//! Trace exporters: Chrome trace-event JSON and the human phase summary.
//!
//! The Chrome exporter emits the JSON Object Format
//! (`{"traceEvents": [...], "otherData": {...}}`) that Perfetto and
//! `chrome://tracing` load directly. Ring overwrites in the recorder can
//! orphan one half of a span; the exporter pairs begin/end events per
//! thread and emits **only matched pairs** (plus instants), so the output
//! is always well-formed: every `B` has an `E` and timestamps are
//! monotone per thread.

use std::collections::BTreeMap;

use crate::util::json::{arr, num, s, Json};

use super::metrics::{self, ExecCounters};
use super::recorder::{Event, EventKind, Phase};

/// Mark which events survive export: instants, and begin/end pairs that
/// actually match (same thread, same kind, properly nested).
fn matched(events: &[Event]) -> Vec<bool> {
    let mut keep = vec![false; events.len()];
    let mut stacks: BTreeMap<u64, Vec<usize>> = BTreeMap::new();
    for (i, e) in events.iter().enumerate() {
        match e.phase {
            Phase::Instant => keep[i] = true,
            Phase::Begin => stacks.entry(e.tid).or_default().push(i),
            Phase::End => {
                let st = stacks.entry(e.tid).or_default();
                // Guards are scoped, so an end normally matches the top
                // of its thread's stack; a ring overwrite that ate the
                // begin leaves a mismatch — drop the orphaned end.
                if let Some(&bi) = st.last() {
                    if events[bi].kind == e.kind {
                        st.pop();
                        keep[bi] = true;
                        keep[i] = true;
                    }
                }
            }
        }
    }
    keep
}

fn chrome_entry(e: &Event) -> Json {
    let mut j = Json::obj();
    j.set("name", s(e.kind.name()))
        .set("cat", s(e.kind.category()))
        .set(
            "ph",
            s(match e.phase {
                Phase::Begin => "B",
                Phase::End => "E",
                Phase::Instant => "i",
            }),
        )
        .set("ts", num(e.ts_us as f64))
        .set("pid", num(1.0))
        .set("tid", num(e.tid as f64));
    if e.phase == Phase::Instant {
        j.set("s", s("t"));
    }
    let mut a = Json::obj();
    a.set("arg", num(e.arg as f64));
    if e.arg2 != 0 {
        a.set("arg2", num(e.arg2 as f64));
    }
    j.set("args", a);
    j
}

fn exec_json(exec: &ExecCounters) -> Json {
    let mut j = Json::obj();
    j.set("own_pops", num(exec.own_pops as f64))
        .set("steals", num(exec.steals as f64))
        .set("help_steals", num(exec.help_steals as f64))
        .set("idle_wakeups", num(exec.idle_wakeups as f64))
        .set("queue_hwm", num(exec.queue_hwm as f64));
    j
}

fn exec_from_json(j: &Json) -> Option<ExecCounters> {
    let f = |k: &str| j.get(k).and_then(Json::as_f64).map(|x| x as u64);
    Some(ExecCounters {
        own_pops: f("own_pops")?,
        steals: f("steals")?,
        help_steals: f("help_steals")?,
        idle_wakeups: f("idle_wakeups")?,
        queue_hwm: f("queue_hwm")?,
    })
}

/// Render a drained event stream as a Chrome trace document. Events are
/// grouped by thread in chronological order; the process-wide executor
/// counters are embedded under `otherData.executor` so `rcc trace
/// summary` can report them after the fact.
pub fn chrome_trace_json(events: &[Event]) -> Json {
    let keep = matched(events);
    // Group by tid: per-thread order is chronological by construction,
    // which keeps per-thread timestamps monotone in the output.
    let mut by_tid: BTreeMap<u64, Vec<Json>> = BTreeMap::new();
    for (i, e) in events.iter().enumerate() {
        if keep[i] {
            by_tid.entry(e.tid).or_default().push(chrome_entry(e));
        }
    }
    let mut entries = Vec::new();
    for (_, v) in by_tid {
        entries.extend(v);
    }
    let mut other = Json::obj();
    other.set("executor", exec_json(&metrics::exec_counters()));
    other.set("dropped_events", num(super::recorder::dropped() as f64));
    let mut doc = Json::obj();
    doc.set("traceEvents", arr(entries))
        .set("displayTimeUnit", s("ms"))
        .set("otherData", other);
    doc
}

/// Drain-free helper: write `events` as a Chrome trace to `path`.
pub fn write_chrome_trace(path: &str, events: &[Event]) -> std::io::Result<()> {
    std::fs::write(path, chrome_trace_json(events).to_string())
}

/// One phase's line in the summary table.
#[derive(Debug, Clone, PartialEq)]
pub struct SummaryRow {
    pub kind: EventKind,
    /// Completed spans (or instants, for instant-only kinds).
    pub count: u64,
    /// Total wall-clock inside spans of this kind, microseconds.
    pub total_us: u64,
}

#[derive(Debug, Clone, Default)]
pub struct TraceSummary {
    /// Rows sorted by total time, busiest phase first.
    pub rows: Vec<SummaryRow>,
    pub threads: usize,
    pub events: usize,
    /// First-to-last event timestamp span, microseconds.
    pub wall_us: u64,
    /// Executor counters, when known (live summary or a trace file's
    /// `otherData.executor`).
    pub exec: Option<ExecCounters>,
    /// Events lost to ring overwrites (live: `recorder::dropped()`; from
    /// a trace file: `otherData.dropped_events`). [`summarize`] is a pure
    /// function of its input stream and leaves this 0 — callers holding
    /// the live counter or a trace document fill it in.
    pub dropped: u64,
}

/// Aggregate an event stream into per-phase counts and total times.
pub fn summarize(events: &[Event]) -> TraceSummary {
    let keep = matched(events);
    let mut count = [0u64; super::recorder::NUM_KINDS];
    let mut total_us = [0u64; super::recorder::NUM_KINDS];
    let mut stacks: BTreeMap<u64, Vec<(EventKind, u64)>> = BTreeMap::new();
    let mut tids: BTreeMap<u64, ()> = BTreeMap::new();
    let mut min_ts = u64::MAX;
    let mut max_ts = 0u64;
    let mut kept = 0usize;
    for (i, e) in events.iter().enumerate() {
        if !keep[i] {
            continue;
        }
        kept += 1;
        tids.insert(e.tid, ());
        min_ts = min_ts.min(e.ts_us);
        max_ts = max_ts.max(e.ts_us);
        match e.phase {
            Phase::Instant => count[e.kind as usize] += 1,
            Phase::Begin => stacks.entry(e.tid).or_default().push((e.kind, e.ts_us)),
            Phase::End => {
                if let Some((kind, begin_ts)) = stacks.entry(e.tid).or_default().pop() {
                    count[kind as usize] += 1;
                    total_us[kind as usize] += e.ts_us.saturating_sub(begin_ts);
                }
            }
        }
    }
    let mut rows: Vec<SummaryRow> = EventKind::ALL
        .iter()
        .filter(|&&k| count[k as usize] > 0)
        .map(|&k| SummaryRow { kind: k, count: count[k as usize], total_us: total_us[k as usize] })
        .collect();
    rows.sort_by(|a, b| b.total_us.cmp(&a.total_us).then(a.kind.cmp(&b.kind)));
    TraceSummary {
        rows,
        threads: tids.len(),
        events: kept,
        wall_us: if kept == 0 { 0 } else { max_ts - min_ts },
        exec: None,
        dropped: 0,
    }
}

/// Summarize a Chrome trace document produced by [`chrome_trace_json`]
/// (used by `rcc trace summary` on a trace file). Returns None when the
/// document has no `traceEvents` array.
pub fn summarize_json(doc: &Json) -> Option<TraceSummary> {
    let entries = doc.get("traceEvents")?.as_arr()?;
    let mut events = Vec::with_capacity(entries.len());
    for e in entries {
        let kind = match e.get("name").and_then(Json::as_str).and_then(EventKind::from_name) {
            Some(k) => k,
            None => continue, // foreign event from another producer
        };
        let phase = match e.get("ph").and_then(Json::as_str) {
            Some("B") => Phase::Begin,
            Some("E") => Phase::End,
            Some("i") => Phase::Instant,
            _ => continue,
        };
        events.push(Event {
            kind,
            phase,
            ts_us: e.get("ts").and_then(Json::as_f64).unwrap_or(0.0) as u64,
            tid: e.get("tid").and_then(Json::as_f64).unwrap_or(0.0) as u64,
            arg: e
                .get("args")
                .and_then(|a| a.get("arg"))
                .and_then(Json::as_f64)
                .unwrap_or(0.0) as u64,
            arg2: e
                .get("args")
                .and_then(|a| a.get("arg2"))
                .and_then(Json::as_f64)
                .unwrap_or(0.0) as u64,
        });
    }
    let mut sum = summarize(&events);
    sum.exec = doc
        .get("otherData")
        .and_then(|o| o.get("executor"))
        .and_then(exec_from_json);
    sum.dropped = doc
        .get("otherData")
        .and_then(|o| o.get("dropped_events"))
        .and_then(Json::as_f64)
        .unwrap_or(0.0) as u64;
    Some(sum)
}

/// Render the per-phase time table (the `rcc trace summary` output).
pub fn render_summary(sum: &TraceSummary) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<14} {:<7} {:>8} {:>12} {:>12}\n",
        "phase", "cat", "count", "total ms", "mean us"
    ));
    for r in &sum.rows {
        let total_ms = r.total_us as f64 / 1_000.0;
        let mean_us = if r.count == 0 { 0.0 } else { r.total_us as f64 / r.count as f64 };
        out.push_str(&format!(
            "{:<14} {:<7} {:>8} {:>12.3} {:>12.1}\n",
            r.kind.name(),
            r.kind.category(),
            r.count,
            total_ms,
            mean_us
        ));
    }
    if sum.rows.is_empty() {
        out.push_str("(no events)\n");
    }
    out.push_str(&format!(
        "threads: {}   events: {}   wall-clock: {:.3} ms\n",
        sum.threads,
        sum.events,
        sum.wall_us as f64 / 1_000.0
    ));
    if let Some(exec) = &sum.exec {
        out.push_str(&exec.render_line());
        out.push('\n');
    }
    if sum.dropped > 0 {
        out.push_str(&format!(
            "warning: {} event(s) lost to ring overwrites — trace a shorter window\n",
            sum.dropped
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(kind: EventKind, phase: Phase, ts_us: u64, tid: u64, arg: u64) -> Event {
        Event { kind, phase, ts_us, tid, arg, arg2: 0 }
    }

    #[test]
    fn export_pairs_and_drops_orphans() {
        use EventKind::*;
        use Phase::*;
        let events = vec![
            ev(Select, Begin, 0, 0, 1),
            ev(Measure, Begin, 1, 1, 5),
            // Orphan end: no begin on tid 0 for measure.
            ev(Measure, End, 2, 0, 9),
            ev(Select, End, 3, 0, 1),
            ev(Measure, End, 4, 1, 5),
            ev(Plan, Instant, 5, 0, 2),
            // Orphan begin: never closed.
            ev(Fold, Begin, 6, 0, 0),
        ];
        let doc = chrome_trace_json(&events);
        let entries = doc.get("traceEvents").unwrap().as_arr().unwrap().to_vec();
        // 2 matched pairs (4 events) + 1 instant.
        assert_eq!(entries.len(), 5);
        // Every B has an E, per tid, and ts is monotone per tid.
        let mut stacks: BTreeMap<u64, Vec<String>> = BTreeMap::new();
        let mut last_ts: BTreeMap<u64, f64> = BTreeMap::new();
        for e in &entries {
            let tid = e.get("tid").unwrap().as_f64().unwrap() as u64;
            let ts = e.get("ts").unwrap().as_f64().unwrap();
            assert!(*last_ts.get(&tid).unwrap_or(&0.0) <= ts);
            last_ts.insert(tid, ts);
            match e.get("ph").unwrap().as_str().unwrap() {
                "B" => stacks
                    .entry(tid)
                    .or_default()
                    .push(e.get("name").unwrap().as_str().unwrap().to_string()),
                "E" => {
                    let top = stacks.entry(tid).or_default().pop().expect("E without B");
                    assert_eq!(top, e.get("name").unwrap().as_str().unwrap());
                }
                "i" => {}
                other => panic!("unexpected ph {other}"),
            }
        }
        assert!(stacks.values().all(|s| s.is_empty()), "unclosed B in export");
        // The document parses back through the summary path.
        let text = doc.to_string();
        let parsed = Json::parse(&text).unwrap();
        let sum = summarize_json(&parsed).unwrap();
        assert_eq!(sum.events, 5);
        assert!(sum.exec.is_some());
    }

    #[test]
    fn dropped_count_renders_warning() {
        let mut sum = summarize(&[]);
        assert_eq!(sum.dropped, 0);
        assert!(!render_summary(&sum).contains("ring overwrites"));
        sum.dropped = 42;
        assert!(render_summary(&sum).contains("42 event(s) lost to ring overwrites"));
    }

    #[test]
    fn summarize_json_reads_dropped_events() {
        let text = r#"{"traceEvents":[],"otherData":{"dropped_events":7}}"#;
        let sum = summarize_json(&Json::parse(text).unwrap()).unwrap();
        assert_eq!(sum.dropped, 7);
    }

    #[test]
    fn summarize_totals_per_phase() {
        use EventKind::*;
        use Phase::*;
        let events = vec![
            ev(Measure, Begin, 10, 0, 1),
            ev(Measure, End, 40, 0, 1),
            ev(Measure, Begin, 50, 1, 2),
            ev(Measure, End, 70, 1, 2),
            ev(Fold, Begin, 80, 0, 2),
            ev(Fold, End, 90, 0, 2),
            ev(CacheProbe, Instant, 85, 0, 1),
        ];
        let sum = summarize(&events);
        assert_eq!(sum.threads, 2);
        assert_eq!(sum.wall_us, 80);
        let measure = sum.rows.iter().find(|r| r.kind == Measure).unwrap();
        assert_eq!(measure.count, 2);
        assert_eq!(measure.total_us, 50);
        let probe = sum.rows.iter().find(|r| r.kind == CacheProbe).unwrap();
        assert_eq!(probe.count, 1);
        assert_eq!(probe.total_us, 0);
        // Busiest phase first.
        assert_eq!(sum.rows[0].kind, Measure);
        let text = render_summary(&sum);
        assert!(text.contains("measure"));
        assert!(text.contains("threads: 2"));
    }
}
