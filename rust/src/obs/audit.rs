//! Decision-provenance audit log: an append-only JSONL record of *why*
//! the search did what it did.
//!
//! The span recorder ([`super::recorder`]) answers "where did the time
//! go"; the audit plane answers "why did the search pick this schedule".
//! When armed (`--audit FILE` / `RCC_AUDIT` / `[obs] audit`) it appends
//! one JSON object per decision to a log file:
//!
//! | kind       | emitted by              | meaning                                  |
//! |------------|-------------------------|------------------------------------------|
//! | `session`  | `coordinator/tuner.rs`  | session header (workload, platform, ...) |
//! | `node`     | `search/mcts.rs`        | MCTS node creation (edge proposal, measured latency, reward, source) |
//! | `select`   | `search/mcts.rs`        | one UCT descent (path + chosen-child visits/Q/UCB) |
//! | `backprop` | `search/mcts.rs`        | reward propagation along a leaf's ancestor path |
//! | `gen`      | `search/evolutionary.rs`| one ES generation (measured slice, best fitness/latency) |
//! | `llm`      | `reasoning/policy.rs`   | one LLM call's proposal attribution (offered/valid/bare/invalid/expanded, retried/degraded) |
//! | `measure`  | search fold paths + `cost/batch.rs` | one hardware measurement (predicted vs measured latency) |
//! | `result`   | `coordinator/tuner.rs`  | one run's outcome (best latency, sample-efficiency curve) |
//!
//! `rcc explain <log>` reconstructs the search tree, the winning path
//! with per-transform reward attribution, abandoned branches, LLM
//! acceptance stats and the cost-model calibration table from this log
//! alone (`report/explain.rs`).
//!
//! ## Determinism contract
//!
//! Same rules as the span recorder: emission is strictly write-only —
//! records are built from values the search already computed, and no
//! emission site may touch RNG state, seeds, plan order or fold order.
//! The disarmed path is a single relaxed atomic load per site. Audit
//! on/off is bit-identical in every `SearchResult` (enforced by
//! `tests/observability.rs`).
//!
//! ## Encoding
//!
//! Same conventions as the session journal: `u64` values that may
//! exceed 2^53 (seeds, fingerprints) are carried as decimal strings or
//! 16-hex, `f64` via shortest-roundtrip `Display`. Failed (quarantined)
//! measurements are encoded as `"failed": true` with the latency field
//! omitted — `f64::INFINITY` has no JSON representation. Torn tails
//! (crash mid-write) are skipped loudly by [`load`], never fatal.

use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Write as _};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock};

use crate::util::json::{s, Json};

static ARMED: AtomicBool = AtomicBool::new(false);

struct Sink {
    path: String,
    writer: BufWriter<File>,
}

fn sink() -> &'static Mutex<Option<Sink>> {
    static S: OnceLock<Mutex<Option<Sink>>> = OnceLock::new();
    S.get_or_init(|| Mutex::new(None))
}

fn lock() -> MutexGuard<'static, Option<Sink>> {
    // A panicking emitter must not wedge the panic hook's flush.
    sink().lock().unwrap_or_else(|p| p.into_inner())
}

/// Is the audit log armed? One relaxed load — the entire cost of a
/// disarmed emission site.
#[inline]
pub fn armed() -> bool {
    ARMED.load(Ordering::Relaxed)
}

/// Arm the audit log: subsequent [`emit`] calls append to `path`
/// (created along with its parent directory; existing logs grow).
pub fn arm(path: &str) -> std::io::Result<()> {
    if let Some(parent) = std::path::Path::new(path).parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    let file = OpenOptions::new().create(true).append(true).open(path)?;
    let mut guard = lock();
    *guard = Some(Sink { path: path.to_string(), writer: BufWriter::new(file) });
    ARMED.store(true, Ordering::Relaxed);
    Ok(())
}

/// Disarm and close the log (flushing buffered records).
pub fn disarm() {
    ARMED.store(false, Ordering::Relaxed);
    let mut guard = lock();
    if let Some(s) = guard.as_mut() {
        s.writer.flush().ok();
    }
    *guard = None;
}

/// Flush buffered records to disk (command end, panic hook).
pub fn flush() {
    if let Some(s) = lock().as_mut() {
        s.writer.flush().ok();
    }
}

/// The armed log's path, if any.
pub fn path() -> Option<String> {
    lock().as_ref().map(|s| s.path.clone())
}

/// Append one record. No-op when disarmed — callers still guard record
/// *construction* behind [`armed`] so the disarmed path stays one load.
pub fn emit(doc: Json) {
    if !armed() {
        return;
    }
    if let Some(s) = lock().as_mut() {
        let _ = writeln!(s.writer, "{}", doc.to_string());
    }
}

/// Start a record: `{"kind": kind, "seed": "<decimal>"}`. The seed is the
/// run's search seed — the correlator that groups one run's records when
/// a session's repeats interleave in the log.
pub fn record(kind: &str, seed: u64) -> Json {
    let mut j = Json::obj();
    j.set("kind", s(kind)).set("seed", s(&seed.to_string()));
    j
}

/// FNV-1a over a string: the stable context hash provenance records use
/// to correlate prompts/exemplar sets without storing their text.
pub fn fingerprint(text: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in text.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0100_0000_01b3);
    }
    h
}

/// Read a `u64` that may be encoded as a decimal string (seeds,
/// fingerprints can exceed 2^53) or, leniently, as a number.
pub fn get_u64_str(j: &Json, key: &str) -> Option<u64> {
    match j.get(key)? {
        Json::Str(t) => t.parse().ok(),
        Json::Num(n) => Some(*n as u64),
        _ => None,
    }
}

/// Load an audit log: one JSON object per line, malformed lines (torn
/// tail after a crash) skipped with a stderr warning, never fatal.
pub fn load(path: &str) -> std::io::Result<Vec<Json>> {
    let text = std::fs::read_to_string(path)?;
    let mut out = Vec::new();
    let mut skipped = 0usize;
    for line in text.lines() {
        if line.trim().is_empty() {
            continue;
        }
        match Json::parse(line) {
            Some(doc) => out.push(doc),
            None => skipped += 1,
        }
    }
    if skipped > 0 {
        eprintln!("warning: skipped {skipped} malformed audit line(s) in {path} (torn tail?)");
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::num;

    #[test]
    fn disarmed_emit_is_a_no_op_and_log_roundtrips() {
        let dir = std::env::temp_dir().join(format!("rcc_audit_{}", std::process::id()));
        let path = dir.join("log.jsonl");
        let path_s = path.to_string_lossy().to_string();

        disarm();
        emit(record("node", 7)); // disarmed: must not create any file
        assert!(!path.exists());

        arm(&path_s).unwrap();
        assert!(armed());
        let mut r = record("node", u64::MAX);
        r.set("latency", num(1.5)).set("id", num(3.0));
        emit(r);
        emit(record("result", 9));
        disarm();
        assert!(!armed());

        // Torn tail: a half-written line is skipped, intact lines load.
        {
            use std::io::Write;
            let mut f = OpenOptions::new().append(true).open(&path).unwrap();
            write!(f, "{{\"kind\": \"nod").unwrap();
        }
        let records = load(&path_s).unwrap();
        assert_eq!(records.len(), 2);
        assert_eq!(records[0].get("kind").and_then(Json::as_str), Some("node"));
        // u64::MAX survives the decimal-string codec (2^53 would not).
        assert_eq!(get_u64_str(&records[0], "seed"), Some(u64::MAX));
        assert_eq!(records[0].get("latency").and_then(Json::as_f64), Some(1.5));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn arm_appends_across_sessions() {
        let dir = std::env::temp_dir().join(format!("rcc_audit_app_{}", std::process::id()));
        let path = dir.join("log.jsonl").to_string_lossy().to_string();
        arm(&path).unwrap();
        emit(record("session", 1));
        disarm();
        arm(&path).unwrap();
        emit(record("session", 2));
        disarm();
        let records = load(&path).unwrap();
        assert_eq!(records.len(), 2);
        assert_eq!(get_u64_str(&records[1], "seed"), Some(2));
        std::fs::remove_dir_all(&dir).ok();
    }
}
