//! The span/event recorder: thread-local ring buffers, one global sink.
//!
//! Cost model: when disabled, every record site is one relaxed atomic
//! load. When enabled, a record is a clock read plus a push under the
//! recording thread's *own* buffer mutex — that mutex is only ever
//! contended by [`drain`], so in steady state it is an uncontended lock
//! (a couple of atomic ops). Buffers are bounded rings: past
//! [`RING_CAP`] events per thread the oldest events are overwritten, so
//! sustained tracing can never grow memory without bound (the exporter
//! drops the orphaned halves of overwritten spans).

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use super::metrics;

/// Number of distinct event kinds (array sizing for the counters).
pub const NUM_KINDS: usize = 18;

/// Events a thread's ring holds before overwriting the oldest.
pub const RING_CAP: usize = 1 << 18;

/// Stable event kinds. The discriminant indexes the per-kind counter
/// arrays; `name()` is the stable wire name used in trace files.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum EventKind {
    Select = 0,
    Expand = 1,
    Propose = 2,
    Measure = 3,
    Backprop = 4,
    Plan = 5,
    CacheProbe = 6,
    Submit = 7,
    Fold = 8,
    LlmCall = 9,
    DbCommit = 10,
    DbGc = 11,
    ServeEnqueue = 12,
    ServeBatch = 13,
    TransferQuery = 14,
    LlmRetry = 15,
    LlmDegrade = 16,
    MeasureFail = 17,
}

impl EventKind {
    pub const ALL: [EventKind; NUM_KINDS] = [
        EventKind::Select,
        EventKind::Expand,
        EventKind::Propose,
        EventKind::Measure,
        EventKind::Backprop,
        EventKind::Plan,
        EventKind::CacheProbe,
        EventKind::Submit,
        EventKind::Fold,
        EventKind::LlmCall,
        EventKind::DbCommit,
        EventKind::DbGc,
        EventKind::ServeEnqueue,
        EventKind::ServeBatch,
        EventKind::TransferQuery,
        EventKind::LlmRetry,
        EventKind::LlmDegrade,
        EventKind::MeasureFail,
    ];

    /// Stable wire name (used as the Chrome trace `name` field).
    pub fn name(self) -> &'static str {
        match self {
            EventKind::Select => "select",
            EventKind::Expand => "expand",
            EventKind::Propose => "propose",
            EventKind::Measure => "measure",
            EventKind::Backprop => "backprop",
            EventKind::Plan => "plan",
            EventKind::CacheProbe => "cache_probe",
            EventKind::Submit => "submit",
            EventKind::Fold => "fold",
            EventKind::LlmCall => "llm_call",
            EventKind::DbCommit => "db_commit",
            EventKind::DbGc => "db_gc",
            EventKind::ServeEnqueue => "serve_enqueue",
            EventKind::ServeBatch => "serve_batch",
            EventKind::TransferQuery => "transfer_query",
            EventKind::LlmRetry => "llm_retry",
            EventKind::LlmDegrade => "llm_degrade",
            EventKind::MeasureFail => "measure_fail",
        }
    }

    /// Chrome trace `cat` field: which subsystem emits the event.
    pub fn category(self) -> &'static str {
        match self {
            EventKind::Select | EventKind::Expand | EventKind::Propose | EventKind::Backprop => {
                "search"
            }
            EventKind::Measure
            | EventKind::Plan
            | EventKind::CacheProbe
            | EventKind::Submit
            | EventKind::Fold
            | EventKind::MeasureFail => "batch",
            EventKind::LlmCall | EventKind::LlmRetry | EventKind::LlmDegrade => "llm",
            EventKind::DbCommit | EventKind::DbGc | EventKind::TransferQuery => "db",
            EventKind::ServeEnqueue | EventKind::ServeBatch => "serve",
        }
    }

    pub fn from_name(s: &str) -> Option<EventKind> {
        EventKind::ALL.iter().copied().find(|k| k.name() == s)
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Begin,
    End,
    Instant,
}

/// One recorded event. `arg` carries the kind-specific payload (see the
/// taxonomy table in the module docs); `arg2` is a secondary payload
/// (`llm_call` uses it for the proposal count, `transfer_query` for the
/// retrieval path).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Event {
    pub kind: EventKind,
    pub phase: Phase,
    /// Microseconds since the recorder epoch (fixed at first use).
    pub ts_us: u64,
    /// Small sequential thread id (registration order, not OS tid).
    pub tid: u64,
    pub arg: u64,
    pub arg2: u64,
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static DROPPED: AtomicU64 = AtomicU64::new(0);

struct Ring {
    buf: Vec<Event>,
    /// Oldest slot once the ring has wrapped.
    head: usize,
}

impl Ring {
    fn new() -> Ring {
        Ring { buf: Vec::new(), head: 0 }
    }

    fn push(&mut self, e: Event) {
        if self.buf.len() < RING_CAP {
            self.buf.push(e);
        } else {
            self.buf[self.head] = e;
            self.head = (self.head + 1) % RING_CAP;
            DROPPED.fetch_add(1, Ordering::Relaxed);
        }
    }

    fn drain(&mut self) -> Vec<Event> {
        let head = std::mem::take(&mut self.head);
        let mut out = std::mem::take(&mut self.buf);
        out.rotate_left(head);
        out
    }
}

struct Sink {
    epoch: Instant,
    /// Every thread's ring, registered on that thread's first event and
    /// kept alive here even after the thread exits, so a late drain
    /// still sees its events.
    rings: Mutex<Vec<Arc<Mutex<Ring>>>>,
    next_tid: AtomicU64,
}

fn sink() -> &'static Sink {
    static SINK: OnceLock<Sink> = OnceLock::new();
    SINK.get_or_init(|| Sink {
        epoch: Instant::now(),
        rings: Mutex::new(Vec::new()),
        next_tid: AtomicU64::new(0),
    })
}

struct Local {
    tid: u64,
    ring: Arc<Mutex<Ring>>,
}

thread_local! {
    static LOCAL: RefCell<Option<Local>> = const { RefCell::new(None) };
}

/// Is the recorder on? One relaxed load — the entire disabled-path cost.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn the recorder on (fixes the timestamp epoch on first use).
pub fn enable() {
    let _ = sink();
    ENABLED.store(true, Ordering::SeqCst);
}

pub fn disable() {
    ENABLED.store(false, Ordering::SeqCst);
}

/// Events lost to ring overwrites since process start.
pub fn dropped() -> u64 {
    DROPPED.load(Ordering::Relaxed)
}

fn record(kind: EventKind, phase: Phase, arg: u64, arg2: u64) {
    let s = sink();
    let ts_us = s.epoch.elapsed().as_micros() as u64;
    LOCAL.with(|slot| {
        let mut slot = slot.borrow_mut();
        let local = slot.get_or_insert_with(|| {
            let ring = Arc::new(Mutex::new(Ring::new()));
            let tid = s.next_tid.fetch_add(1, Ordering::Relaxed);
            s.rings.lock().unwrap().push(Arc::clone(&ring));
            Local { tid, ring }
        });
        local
            .ring
            .lock()
            .unwrap()
            .push(Event { kind, phase, ts_us, tid: local.tid, arg, arg2 });
    });
}

/// Drain every thread's ring into one stream, sorted by timestamp.
/// Per-thread chronological order is preserved for equal timestamps
/// (stable sort over per-ring-ordered input), which the exporter's
/// begin/end pairing relies on.
pub fn drain() -> Vec<Event> {
    let s = sink();
    let rings: Vec<Arc<Mutex<Ring>>> = s.rings.lock().unwrap().clone();
    let mut out = Vec::new();
    for ring in rings {
        out.extend(ring.lock().unwrap().drain());
    }
    out.sort_by_key(|e| e.ts_us);
    out
}

/// Record a point event (no duration).
#[inline]
pub fn instant(kind: EventKind, arg: u64) {
    instant2(kind, arg, 0);
}

/// [`instant`] with a secondary payload.
#[inline]
pub fn instant2(kind: EventKind, arg: u64, arg2: u64) {
    if enabled() {
        record(kind, Phase::Instant, arg, arg2);
        metrics::record_instant(kind);
    }
}

/// Open a span; the returned guard closes it on drop. When the recorder
/// is disabled this constructs an inert guard and records nothing.
#[inline]
pub fn span(kind: EventKind, arg: u64) -> SpanGuard {
    span2(kind, arg, 0)
}

/// [`span`] with a secondary payload.
#[inline]
pub fn span2(kind: EventKind, arg: u64, arg2: u64) -> SpanGuard {
    let start = if enabled() {
        record(kind, Phase::Begin, arg, arg2);
        Some(Instant::now())
    } else {
        None
    };
    SpanGuard { kind, arg, arg2, start }
}

/// Guard for an open span. Whether it records was fixed at construction,
/// so an enable/disable flip mid-span cannot orphan a begin event on
/// this thread.
pub struct SpanGuard {
    kind: EventKind,
    arg: u64,
    arg2: u64,
    start: Option<Instant>,
}

impl SpanGuard {
    /// Update the payloads carried on the span's end event (e.g. a token
    /// count only known after the work ran).
    pub fn set_args(&mut self, arg: u64, arg2: u64) {
        self.arg = arg;
        self.arg2 = arg2;
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if let Some(start) = self.start {
            metrics::record_span(self.kind, start.elapsed().as_nanos() as u64);
            record(self.kind, Phase::End, self.arg, self.arg2);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_names_roundtrip() {
        for k in EventKind::ALL {
            assert_eq!(EventKind::from_name(k.name()), Some(k));
            assert!(!k.category().is_empty());
        }
        assert_eq!(EventKind::from_name("no_such_kind"), None);
    }

    #[test]
    fn disabled_span_records_nothing_and_is_inert() {
        // The recorder is off by default in the test binary; a span built
        // while disabled must never record, even across many drops.
        assert!(!enabled());
        for i in 0..100 {
            let mut g = span(EventKind::Measure, i);
            g.set_args(i, 1);
        }
        instant(EventKind::Plan, 7);
    }

    #[test]
    fn ring_overwrites_oldest_when_full() {
        let mut r = Ring::new();
        let ev = |arg| Event {
            kind: EventKind::Measure,
            phase: Phase::Instant,
            ts_us: arg,
            tid: 0,
            arg,
            arg2: 0,
        };
        for i in 0..(RING_CAP as u64 + 10) {
            r.push(ev(i));
        }
        let out = r.drain();
        assert_eq!(out.len(), RING_CAP);
        // Oldest 10 overwritten; order is oldest-first.
        assert_eq!(out[0].arg, 10);
        assert_eq!(out[RING_CAP - 1].arg, RING_CAP as u64 + 9);
    }
}
