//! Crate-wide observability: structured spans, counters, trace export,
//! and the decision-provenance audit log.
//!
//! The telemetry plane has four parts:
//!
//! - [`recorder`] — a lock-cheap span/event recorder. Each thread records
//!   into its own bounded ring buffer (one uncontended mutex per event);
//!   a global sink drains every ring into one chronologically-ordered
//!   stream. When tracing is disabled (the default) every record site is
//!   a single relaxed atomic load — a no-op on the hot path. Ring
//!   overwrites are counted ([`recorder::dropped`]) and surfaced in the
//!   trace summary and session telemetry.
//! - [`metrics`] — always-on process-wide counters: per-phase
//!   count/total-time aggregates (updated at span end, snapshotable
//!   without draining events) and the executor's steal / own-pop /
//!   idle-wakeup / queue-high-water counters.
//! - [`export`] — exporters: Chrome trace-event JSON (loadable in
//!   Perfetto / `chrome://tracing`) and the human per-phase summary
//!   table behind `rcc trace summary`.
//! - [`audit`] — the decision-provenance plane: an append-only JSONL log
//!   of search-tree events (node/select/backprop/gen), LLM proposal
//!   attribution (`llm`), measurements (`measure`) and run outcomes
//!   (`session`/`result`), armed independently of tracing via
//!   `--audit FILE` / `RCC_AUDIT` / `[obs] audit` and consumed by
//!   `rcc explain` (see the taxonomy table in [`audit`]'s docs).
//!
//! ## Determinism contract
//!
//! Recording is strictly write-only with respect to the rest of the
//! system: it reads the clock and bumps atomics, and **never** touches
//! seeds, RNG streams, plan order or fold order. Tracing on vs off is
//! bit-identical in every `SearchResult` (enforced by
//! `tests/observability.rs`). Measurement events carry their plan-time
//! submission index in `arg`, so a `workers=N` trace is diffable against
//! a `workers=1` trace event-for-event.
//!
//! ## Event taxonomy
//!
//! | kind            | cat    | span/instant | `arg`                      |
//! |-----------------|--------|--------------|----------------------------|
//! | `select`        | search | span         | iteration                  |
//! | `expand`        | search | span         | pending-leaf index         |
//! | `propose`       | search | span         | node visit count           |
//! | `measure`       | batch  | span         | plan-time submission index |
//! | `backprop`      | search | span         | leaf index                 |
//! | `plan`          | batch  | instant      | submission index           |
//! | `cache_probe`   | batch  | instant      | 1 = hit, 0 = miss          |
//! | `submit`        | batch  | instant      | submission index           |
//! | `fold`          | batch  | span         | jobs folded                |
//! | `llm_call`      | llm    | span         | prompt tokens (`arg2` = proposals) |
//! | `db_commit`     | db     | span         | records committed          |
//! | `db_gc`         | db     | span         | records kept               |
//! | `serve_enqueue` | serve  | instant      | queue depth (`arg2`: 1 = admitted, 0 = rejected) |
//! | `serve_batch`   | serve  | span         | requests started this tick (`arg2` = slot occupancy) |
//! | `transfer_query` | db    | span         | candidates considered (`arg2`: 1 = index, 0 = scan) |
//! | `llm_retry`     | llm    | instant      | attempt index (`arg2`: 1 = timeout, 0 = error) |
//! | `llm_degrade`   | llm    | instant      | policy call index          |
//! | `measure_fail`  | batch  | instant      | plan-time submission index |
//!
//! The last three only ever fire under an armed fault plan
//! (`util::faults`); stock runs never emit them.

pub mod audit;
pub mod export;
pub mod metrics;
pub mod recorder;

pub use export::{
    chrome_trace_json, render_summary, summarize, summarize_json, write_chrome_trace,
    SummaryRow, TraceSummary,
};
pub use metrics::{exec_counters, phase_totals, ExecCounters, PhaseStat, PhaseTotals};
pub use recorder::{
    disable, drain, dropped, enable, enabled, instant, instant2, span, span2, Event, EventKind,
    Phase, SpanGuard,
};
