//! Always-on counters: per-phase time aggregates and executor counters.
//!
//! Unlike the event recorder, these are plain process-wide relaxed
//! atomics that cost one `fetch_add` at sites that already take a lock —
//! cheap enough to leave on unconditionally. Phase aggregates are only
//! *updated* while tracing is enabled (span guards are inert otherwise);
//! the executor counters count always, so `Executor::stats()` and the
//! serve summary work without tracing.
//!
//! Snapshots are values ([`PhaseTotals`], [`ExecCounters`]) with
//! `delta_since` helpers, so a session can report just its own share of
//! the process-wide totals.

use std::array;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;

use super::recorder::{EventKind, NUM_KINDS};

struct Counters {
    count: [AtomicU64; NUM_KINDS],
    total_ns: [AtomicU64; NUM_KINDS],
    exec_own_pops: AtomicU64,
    exec_steals: AtomicU64,
    exec_help_steals: AtomicU64,
    exec_idle_wakeups: AtomicU64,
    exec_queue_hwm: AtomicU64,
}

fn counters() -> &'static Counters {
    static C: OnceLock<Counters> = OnceLock::new();
    C.get_or_init(|| Counters {
        count: array::from_fn(|_| AtomicU64::new(0)),
        total_ns: array::from_fn(|_| AtomicU64::new(0)),
        exec_own_pops: AtomicU64::new(0),
        exec_steals: AtomicU64::new(0),
        exec_help_steals: AtomicU64::new(0),
        exec_idle_wakeups: AtomicU64::new(0),
        exec_queue_hwm: AtomicU64::new(0),
    })
}

pub(crate) fn record_span(kind: EventKind, ns: u64) {
    let c = counters();
    c.count[kind as usize].fetch_add(1, Ordering::Relaxed);
    c.total_ns[kind as usize].fetch_add(ns, Ordering::Relaxed);
}

pub(crate) fn record_instant(kind: EventKind) {
    counters().count[kind as usize].fetch_add(1, Ordering::Relaxed);
}

// ---- executor counter feeds (called from util::executor) --------------

pub(crate) fn exec_own_pop() {
    counters().exec_own_pops.fetch_add(1, Ordering::Relaxed);
}

pub(crate) fn exec_steal() {
    counters().exec_steals.fetch_add(1, Ordering::Relaxed);
}

pub(crate) fn exec_help_steal() {
    counters().exec_help_steals.fetch_add(1, Ordering::Relaxed);
}

pub(crate) fn exec_idle_wakeup() {
    counters().exec_idle_wakeups.fetch_add(1, Ordering::Relaxed);
}

pub(crate) fn exec_queue_depth(depth: u64) {
    counters().exec_queue_hwm.fetch_max(depth, Ordering::Relaxed);
}

/// One phase's aggregate: how many spans/instants, total span time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PhaseStat {
    pub count: u64,
    pub total_ns: u64,
}

impl Default for PhaseStat {
    fn default() -> Self {
        PhaseStat { count: 0, total_ns: 0 }
    }
}

/// Snapshot of every phase's aggregate.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PhaseTotals {
    stats: [PhaseStat; NUM_KINDS],
}

impl Default for PhaseTotals {
    fn default() -> Self {
        PhaseTotals { stats: [PhaseStat::default(); NUM_KINDS] }
    }
}

impl PhaseTotals {
    pub fn get(&self, kind: EventKind) -> PhaseStat {
        self.stats[kind as usize]
    }

    /// This snapshot minus an earlier one (saturating — counters only grow).
    pub fn delta_since(&self, earlier: &PhaseTotals) -> PhaseTotals {
        let mut out = PhaseTotals::default();
        for i in 0..NUM_KINDS {
            out.stats[i] = PhaseStat {
                count: self.stats[i].count.saturating_sub(earlier.stats[i].count),
                total_ns: self.stats[i].total_ns.saturating_sub(earlier.stats[i].total_ns),
            };
        }
        out
    }

    /// Phases with at least one recorded span/instant.
    pub fn nonzero(&self) -> Vec<(EventKind, PhaseStat)> {
        EventKind::ALL
            .iter()
            .map(|&k| (k, self.get(k)))
            .filter(|(_, s)| s.count > 0)
            .collect()
    }
}

/// Snapshot the process-wide per-phase aggregates.
pub fn phase_totals() -> PhaseTotals {
    let c = counters();
    let mut out = PhaseTotals::default();
    for i in 0..NUM_KINDS {
        out.stats[i] = PhaseStat {
            count: c.count[i].load(Ordering::Relaxed),
            total_ns: c.total_ns[i].load(Ordering::Relaxed),
        };
    }
    out
}

/// Process-wide executor counters (all executors in this process;
/// per-executor, per-worker breakdowns come from `Executor::stats()`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ExecCounters {
    pub own_pops: u64,
    pub steals: u64,
    /// Steals by helping submitters (threads waiting on a group).
    pub help_steals: u64,
    pub idle_wakeups: u64,
    /// High-water mark of any single deque's depth.
    pub queue_hwm: u64,
}

impl ExecCounters {
    pub fn delta_since(&self, earlier: &ExecCounters) -> ExecCounters {
        ExecCounters {
            own_pops: self.own_pops.saturating_sub(earlier.own_pops),
            steals: self.steals.saturating_sub(earlier.steals),
            help_steals: self.help_steals.saturating_sub(earlier.help_steals),
            idle_wakeups: self.idle_wakeups.saturating_sub(earlier.idle_wakeups),
            // A high-water mark is not a monotone sum; report the later one.
            queue_hwm: self.queue_hwm,
        }
    }

    pub fn render_line(&self) -> String {
        format!(
            "executor: own_pops={} steals={} help_steals={} idle_wakeups={} queue_hwm={}",
            self.own_pops, self.steals, self.help_steals, self.idle_wakeups, self.queue_hwm
        )
    }
}

/// Snapshot the process-wide executor counters.
pub fn exec_counters() -> ExecCounters {
    let c = counters();
    ExecCounters {
        own_pops: c.exec_own_pops.load(Ordering::Relaxed),
        steals: c.exec_steals.load(Ordering::Relaxed),
        help_steals: c.exec_help_steals.load(Ordering::Relaxed),
        idle_wakeups: c.exec_idle_wakeups.load(Ordering::Relaxed),
        queue_hwm: c.exec_queue_hwm.load(Ordering::Relaxed),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_totals_delta() {
        let mut a = PhaseTotals::default();
        let mut b = PhaseTotals::default();
        a.stats[EventKind::Measure as usize] = PhaseStat { count: 3, total_ns: 300 };
        b.stats[EventKind::Measure as usize] = PhaseStat { count: 10, total_ns: 1_300 };
        b.stats[EventKind::Fold as usize] = PhaseStat { count: 1, total_ns: 50 };
        let d = b.delta_since(&a);
        assert_eq!(d.get(EventKind::Measure), PhaseStat { count: 7, total_ns: 1_000 });
        assert_eq!(d.get(EventKind::Fold), PhaseStat { count: 1, total_ns: 50 });
        assert_eq!(d.get(EventKind::Select).count, 0);
        let names: Vec<&str> = d.nonzero().iter().map(|(k, _)| k.name()).collect();
        assert_eq!(names, vec!["measure", "fold"]);
    }

    #[test]
    fn exec_counters_delta_keeps_hwm() {
        let a = ExecCounters { own_pops: 5, steals: 2, help_steals: 1, idle_wakeups: 4, queue_hwm: 9 };
        let b = ExecCounters {
            own_pops: 15,
            steals: 2,
            help_steals: 3,
            idle_wakeups: 10,
            queue_hwm: 12,
        };
        let d = b.delta_since(&a);
        assert_eq!(d.own_pops, 10);
        assert_eq!(d.steals, 0);
        assert_eq!(d.help_steals, 2);
        assert_eq!(d.queue_hwm, 12);
        assert!(d.render_line().contains("steals=0"));
    }
}
