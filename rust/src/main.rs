//! `rcc` — the REASONING COMPILER command-line interface.
//!
//! Subcommands cover the whole system: single tuning runs, strategy
//! comparisons, every paper table/figure regenerator, the serving demo,
//! artifact inspection and prompt dumps. See `rcc help`.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Result};

use reasoning_compiler::coordinator::{
    run_e2e, run_session, tune_models, tune_models_on, Registry, Server, ServerConfig,
    SessionTelemetry, Strategy, TuneConfig, DEFAULT_DB_PATH,
};
use reasoning_compiler::db::{workload_fingerprint, Database, TuningRecord};
use reasoning_compiler::cost::{features, Platform};
use reasoning_compiler::obs;
use reasoning_compiler::reasoning::{self, ModelProfile, PromptContext};
use reasoning_compiler::report::{ablations, costs, figure3, platforms, Scale};
use reasoning_compiler::runtime::Manifest;
use reasoning_compiler::schedule::{Schedule, Transform};
use reasoning_compiler::tir::{printer, workload, WorkloadId};
use reasoning_compiler::util::cli::Args;
use reasoning_compiler::util::executor::Executor;
use reasoning_compiler::util::faults;
use reasoning_compiler::util::rng::Pcg;
use reasoning_compiler::util::json::Json;

const HELP: &str = "\
rcc — REASONING COMPILER (NeurIPS 2025 reproduction)

USAGE: rcc <command> [--key value] [--flag]

Tuning
  tune        Run one tuning session. Persists records to the tuning
              database (results/tuning_db.jsonl) and warm-starts from it.
              --strategy es|mcts|rc --workload NAME --platform NAME
              --budget N --repeats N --seed N --model NAME
              --history-depth N --branching N [--config FILE]
              --db FILE | --no-db  --no-warm-start --warm-top-k N
              --transfer | --no-transfer  cross-workload transfer tuning
                             (rebased warm starts + LLM exemplars from
                             structurally similar recorded workloads)
              --transfer-top-k N  similar records to rebase (default 4)
              --transfer-index | --no-transfer-index  ANN transfer index
                             over the database (sublinear retrieval on
                             large dbs; small dbs stay on the exact scan)
              --transfer-index-threshold N  records before retrieval
                             switches from scan to index (default 256)
              --share-repeat-cache  pool measurements across a session's
                             repeats (saves samples; waives the repeats'
                             independence contract — default off)
              --workers N    total parallelism of the one persistent
                             executor all parallel sites share (repeats,
                             batched evaluation, serve --tune fleets;
                             0 = auto: RCC_WORKERS env or all cores;
                             1 = fully serial; results identical for
                             every N)
              --eval-batch N MCTS leaves measured per iteration (1 =
                             serial trajectory; >1 = leaf-parallel search,
                             deterministic per seed; 0 = match --workers)
              --journal FILE crash-safe session checkpoint (append-only
                             JSONL, one fsynced entry per completed
                             repeat; `[session] journal` in --config)
              --resume FILE  resume a killed session from its journal:
                             journaled repeats replay verbatim, the rest
                             re-run — bit-identical to the uninterrupted
                             run; new checkpoints append to the same file
              --faults SPEC  deterministic fault injection (RCC_FAULTS env
                             or `[faults] spec` in --config also work;
                             CLI > env > config), e.g. llm_error=0.05,
                             llm_timeout=0.02,measure_fail=0.03,
                             crash_at_step=40,seed=7
  compare     Run all three strategies head-to-head on one benchmark.
  e2e         Tune the end-to-end Llama-3-8B task set.

Tuning database
  db stats    Aggregate stats of the tuning-record database. [--db FILE]
  db top      Best recorded schedules for one (workload, platform).
              --workload NAME --platform NAME [--k N] [--db FILE]
  db gc       Compact the database: keep the top-k records per
              (workload, platform), drop the rest. [--k N] [--db FILE]
              [--reap-dominated]  also drop records superseded by fresher
              equal-or-faster work on the same workload (the transfer
              aging policy; default keeps them, down-weighted)
  db synth    Append a synthetic record corpus (for transfer-index
              benchmarking). [--records N] [--seed N] [--platform NAME]
              [--db FILE]

Transfer tuning (cross-workload reuse of the database)
  transfer match      Records from structurally similar workloads (same
                      shape class, ranked by feature distance).
                      --workload NAME --platform NAME [--k N] [--db FILE]
  transfer rebase     Rebase the best similar record's trace onto a
                      workload and verify it replays. Same options.
  transfer exemplars  Print the few-shot exemplar block the LLM prompts
                      embed for a workload. Same options.
  Transfer actions attach the ANN index sidecar (<db>.idx) and report the
  retrieval path taken (`retrieval: index|scan`); databases smaller than
  --transfer-index-threshold (default 256) always use the exact scan.
  --no-transfer-index forces the scan at any size.

Paper experiments (each accepts --scale smoke|default|full, --seed, --out DIR)
  figure3     Fig. 3 / Table 3 convergence curves
  table1      Layer-wise sample efficiency across 5 platforms
  table2      End-to-end Llama-3-8B across 5 platforms
  table4      LLM-choice ablation (Fig. 4a)
  table5      Historical-trace-depth ablation (Fig. 4b)
  table6      MCTS branching-factor ablation
  table7      LLM API cost accounting
  table8      Proposal fallback rates
  all         Run every experiment and write results/

Registry
  history     List persisted tuning runs (results/runs/).
  best        Show + replay the best recorded schedule.
              --workload NAME --platform NAME

Observability
  trace summary   Per-phase time table + executor counters of a recorded
                  trace file. --trace FILE (defaults to RCC_TRACE)
  explain         Reconstruct *why* a session picked its schedule from a
                  decision-provenance audit log: the winning path with
                  per-transform reward attribution, abandoned branches,
                  LLM proposal acceptance stats, and the cost-model
                  calibration table. Takes an audit log path or a
                  recorded run id (results/runs/). [--json]
  Every command accepts --trace FILE (or the RCC_TRACE env var) to record
  a Chrome trace-event JSON of the run — load it at ui.perfetto.dev.
  `--config` files can set it as `[obs] trace`. Tracing never changes
  results: searches are bit-identical with it on or off. With a trace
  armed, a panic still exports it (plus a telemetry summary to stderr).
  Every command likewise accepts --audit FILE (or RCC_AUDIT, or `[obs]
  audit` in --config) to append a decision-provenance JSONL log — every
  MCTS node/selection/backprop, ES generation, LLM proposal, and
  predicted-vs-measured pair. Audit on/off is also bit-identical; a
  panic flushes the armed log.

Fault tolerance
  With an armed fault plan (--faults / RCC_FAULTS), injected LLM failures
  are retried (bounded attempts, deterministic backoff) and then degrade
  to the sampler fallback; failed hardware measurements are quarantined —
  the sample is spent but nothing is cached or recorded — and the search
  keeps going. `crash_at_step=N` kills the session once the measurement
  clock passes N; with --journal armed, `tune --resume` restarts it
  bit-identically. With no plan armed every workload is bit-identical to
  a build without the harness.

Serving & inspection
  serve       Continuous-batching serving plane: bounded per-model ingress
              with admission control (typed Overloaded rejection), per-
              request slot admit/evict each scheduling tick, round-robin
              fairness, deadline eviction, and per-model p50/p99 +
              admission counters in the report. Runs over the AOT
              artifacts, or (--sim, or when artifacts/xla are absent) a
              simulated backend whose service times come from the cost
              model. --requests N --max-batch N [--db FILE]
              --sim --models a,b     simulated backend + model list
              --queue-cap N          hard bound on any ingress queue
              --target-delay N       ticks of queueing delay the per-model
                                     admission budget is derived from
                                     (tuned models earn deeper queues)
              --min-fill N --max-wait N   batch amortization + forced
                                     flush of non-full batches (counted)
              --max-queue-ticks N    evict queued requests older than this
              --burst N              load generator max arrivals per tick
              --tune         tune every registered model in the background
                             *while serving* — the fleet shares the serve
                             executor at low priority (serve preempts) and
                             commits to the shared database (file-locked)
              --tune-budget N --tune-repeats N  per-model session size
  artifacts   List + smoke-run the AOT artifacts.
  show        Print a workload's TIR. --workload NAME
  prompt      Print a real optimization prompt + simulated LLM response.
  platforms   List the hardware platform descriptors.
  models      List the LLM model profiles.
";

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let cmd = args.subcommand.clone().unwrap_or_else(|| "help".to_string());
    // `--trace FILE` / `RCC_TRACE=FILE` arm the event recorder for any
    // command; the trace is exported after the command finishes (also on
    // error — a failing run's trace is the one worth looking at). The
    // `trace` subcommand itself reads files, so it never arms recording.
    let trace_path = if cmd == "trace" {
        None
    } else {
        args.opt("trace")
            .map(String::from)
            .or_else(|| std::env::var("RCC_TRACE").ok().filter(|s| !s.is_empty()))
    };
    if trace_path.is_some() {
        obs::enable();
    }
    // `--audit FILE` / `RCC_AUDIT=FILE` arm the decision-provenance log
    // for any command; records append as the search runs and the log is
    // flushed after the command (and on panic). The read-only `trace` and
    // `explain` subcommands never arm it — explaining a log must not grow
    // it. A config-file `[obs] audit` arms later, inside cmd_tune.
    let audit_path = if cmd == "trace" || cmd == "explain" {
        None
    } else {
        args.opt("audit")
            .map(String::from)
            .or_else(|| std::env::var("RCC_AUDIT").ok().filter(|s| !s.is_empty()))
    };
    if let Some(path) = &audit_path {
        if let Err(e) = obs::audit::arm(path) {
            eprintln!("error: cannot open audit log {path}: {e}");
            std::process::exit(2);
        }
    }
    // A panicking run's observability is the observability worth having:
    // export the armed trace (plus a telemetry summary to stderr) and
    // flush the armed audit log before unwinding finishes, then defer to
    // the default hook's backtrace. Audit arming is checked dynamically —
    // a config-file `[obs] audit` arms after this hook is installed.
    {
        let hook_trace = trace_path.clone();
        let default_hook = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            default_hook(info);
            if let Some(hook_path) = &hook_trace {
                let events = obs::drain();
                if let Some(parent) = Path::new(hook_path).parent() {
                    if !parent.as_os_str().is_empty() {
                        std::fs::create_dir_all(parent).ok();
                    }
                }
                match obs::write_chrome_trace(hook_path, &events) {
                    Ok(()) => eprintln!(
                        "panic: exported {} trace events to {hook_path} (load at ui.perfetto.dev)",
                        events.len()
                    ),
                    Err(e) => eprintln!("panic: failed to export trace to {hook_path}: {e:#}"),
                }
                let mut summary = obs::summarize(&events);
                summary.exec = Some(obs::exec_counters());
                summary.dropped = obs::dropped();
                eprint!("{}", obs::render_summary(&summary));
            }
            if obs::audit::armed() {
                obs::audit::flush();
                if let Some(p) = obs::audit::path() {
                    eprintln!("panic: audit decision log flushed to {p} (see `rcc explain`)");
                }
            }
        }));
    }
    // RCC_FAULTS arms the deterministic fault-injection harness for any
    // command; `tune` additionally honors `--faults` / `[faults] spec`
    // with CLI > env > config precedence. A bad spec is a usage error.
    if let Ok(spec) = std::env::var("RCC_FAULTS") {
        if !spec.is_empty() {
            match faults::FaultPlan::parse(&spec) {
                Ok(plan) => faults::arm(&plan),
                Err(e) => {
                    eprintln!("error: bad RCC_FAULTS spec: {e}");
                    std::process::exit(2);
                }
            }
        }
    }
    let result = dispatch(&cmd, &args);
    if let Some(path) = &trace_path {
        if let Err(e) = export_trace(path) {
            eprintln!("warning: failed to export trace to {path}: {e:#}");
        }
    }
    // Flush the audit log (CLI/env-armed here, or config-armed inside
    // cmd_tune) and tell the user where it went — the greppable line CI
    // keys on before running `rcc explain`.
    if obs::audit::armed() {
        obs::audit::flush();
        if let Some(p) = obs::audit::path() {
            println!("audit decision log: {p}");
        }
    }
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Drain the recorder into a Chrome trace-event JSON at `path` and print
/// the per-phase summary table.
fn export_trace(path: &str) -> Result<()> {
    let events = obs::drain();
    if let Some(parent) = Path::new(path).parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    obs::write_chrome_trace(path, &events)?;
    let mut summary = obs::summarize(&events);
    summary.exec = Some(obs::exec_counters());
    summary.dropped = obs::dropped();
    println!("\ntrace: {} events -> {path} (load at ui.perfetto.dev)", events.len());
    print!("{}", obs::render_summary(&summary));
    if summary.dropped > 0 {
        eprintln!(
            "warning: {} trace event(s) lost to ring overwrites — trace a shorter window",
            summary.dropped
        );
    }
    Ok(())
}

fn dispatch(cmd: &str, args: &Args) -> Result<()> {
    match cmd {
        "help" | "--help" => {
            println!("{HELP}");
            Ok(())
        }
        "tune" => cmd_tune(args),
        "trace" => cmd_trace(args),
        "explain" => cmd_explain(args),
        "db" => cmd_db(args),
        "transfer" => cmd_transfer(args),
        "history" => cmd_history(),
        "best" => cmd_best(args),
        "compare" => cmd_compare(args),
        "e2e" => cmd_e2e(args),
        "figure3" | "table1" | "table2" | "table4" | "table5" | "table6" | "table7"
        | "table8" | "all" => cmd_experiment(cmd, args),
        "serve" => cmd_serve(args),
        "artifacts" => cmd_artifacts(),
        "show" => cmd_show(args),
        "prompt" => cmd_prompt(args),
        "platforms" => {
            for p in Platform::all() {
                println!(
                    "{:<12} {:<18} {} cores, {}-lane SIMD, {:.2} GHz, L1 {}K L2 {}K L3 {}M, {} GB/s DRAM",
                    p.name, p.display, p.cores, p.simd_lanes, p.freq_ghz,
                    p.l1d_bytes >> 10, p.l2_bytes >> 10, p.l3_bytes >> 20, p.dram_gbps
                );
            }
            Ok(())
        }
        "models" => {
            for m in ModelProfile::all() {
                println!(
                    "{:<16} {:<28} quality {:.2}, context use {:.2}, expected fallback {:.2}%",
                    m.name,
                    m.display,
                    m.quality,
                    m.context_use,
                    m.expected_fallback_rate() * 100.0
                );
            }
            Ok(())
        }
        other => Err(anyhow!("unknown command {other:?}; see `rcc help`")),
    }
}

fn config_from(args: &Args) -> Result<TuneConfig> {
    let mut cfg = match args.opt("config") {
        Some(path) => TuneConfig::from_file(Path::new(path))?,
        None => TuneConfig::default(),
    };
    cfg.apply_cli(args);
    Ok(cfg)
}

/// `rcc trace summary --trace FILE`: per-phase table of a recorded trace.
fn cmd_trace(args: &Args) -> Result<()> {
    let action = args.positional.first().map(|s| s.as_str()).unwrap_or("summary");
    if action != "summary" {
        return Err(anyhow!(
            "unknown trace action {action:?}; use `trace summary --trace FILE`"
        ));
    }
    let path = args
        .opt("trace")
        .map(String::from)
        .or_else(|| args.positional.get(1).cloned())
        .or_else(|| std::env::var("RCC_TRACE").ok().filter(|s| !s.is_empty()))
        .ok_or_else(|| anyhow!("trace summary needs --trace FILE (or RCC_TRACE)"))?;
    let text = std::fs::read_to_string(&path)
        .map_err(|e| anyhow!("reading trace {path}: {e}"))?;
    let doc = Json::parse(&text).ok_or_else(|| anyhow!("{path} is not valid JSON"))?;
    let summary = obs::summarize_json(&doc)
        .ok_or_else(|| anyhow!("{path} is not a Chrome trace-event file"))?;
    println!("trace {path}:");
    print!("{}", obs::render_summary(&summary));
    Ok(())
}

/// `rcc explain <audit-log | run-id> [--json]`: reconstruct a session's
/// decision provenance. A path that exists on disk is read as an audit
/// JSONL log; anything else is resolved as a registry run id.
fn cmd_explain(args: &Args) -> Result<()> {
    use reasoning_compiler::report::explain::{render_run_record, Explanation};
    let target = args
        .positional
        .first()
        .cloned()
        .or_else(|| args.opt("audit").map(String::from))
        .or_else(|| std::env::var("RCC_AUDIT").ok().filter(|s| !s.is_empty()))
        .ok_or_else(|| {
            anyhow!("explain needs an audit log path or a recorded run id (see `rcc history`)")
        })?;
    let json_out = args.has_flag("json");
    if Path::new(&target).exists() {
        let records = obs::audit::load(&target)
            .map_err(|e| anyhow!("reading audit log {target}: {e}"))?;
        if records.is_empty() {
            return Err(anyhow!("audit log {target} holds no records"));
        }
        let ex = Explanation::from_records(&records);
        if json_out {
            println!("{}", ex.to_json().to_pretty());
        } else {
            print!("{}", ex.render());
        }
        return Ok(());
    }
    let reg = Registry::default_location()?;
    let path = reg.dir.join(format!("{target}.json"));
    let text = std::fs::read_to_string(&path).map_err(|_| {
        anyhow!(
            "{target} is neither an audit log path nor a recorded run id in {}",
            reg.dir.display()
        )
    })?;
    let doc =
        Json::parse(&text).ok_or_else(|| anyhow!("malformed run record {}", path.display()))?;
    if json_out {
        println!("{}", doc.to_pretty());
    } else {
        print!("{}", render_run_record(&doc));
    }
    Ok(())
}

fn cmd_tune(args: &Args) -> Result<()> {
    let mut cfg = config_from(args)?;
    // The CLI persists to the conventional database location unless the
    // user opts out; library callers stay db-less by default.
    if cfg.db_path.is_none() && !args.has_flag("no-db") {
        cfg.db_path = Some(DEFAULT_DB_PATH.to_string());
    }
    // A config-file `[obs] trace` arms the recorder here (CLI `--trace` /
    // RCC_TRACE were handled in main and take precedence); export at the
    // end of the command mirrors main's lifecycle.
    let config_trace = match &cfg.trace_path {
        Some(p) if !obs::enabled() => {
            obs::enable();
            Some(p.clone())
        }
        _ => None,
    };
    // Same pattern for a config-file `[obs] audit`: CLI `--audit` /
    // RCC_AUDIT were armed in main and win; main's post-dispatch flush
    // handles this log too.
    if !obs::audit::armed() {
        if let Some(p) = &cfg.audit_path {
            obs::audit::arm(p).map_err(|e| anyhow!("cannot open audit log {p}: {e}"))?;
        }
    }
    // Arm fault injection: `--faults` wins over RCC_FAULTS (armed in
    // main), which wins over a config-file `[faults] spec`.
    let env_faults =
        std::env::var("RCC_FAULTS").map(|s| !s.is_empty()).unwrap_or(false);
    if args.opt("faults").is_some() || !env_faults {
        if let Some(spec) = &cfg.faults_spec {
            let plan = faults::FaultPlan::parse(spec)
                .map_err(|e| anyhow!("bad --faults spec: {e}"))?;
            faults::arm(&plan);
        }
    }
    println!(
        "tuning {} on {} with {} (budget {}, {} repeats)...",
        cfg.workload,
        cfg.platform,
        cfg.strategy.display(),
        cfg.budget,
        cfg.repeats
    );
    let session = run_session(&cfg)?;
    if let Some(j) = &cfg.resume_from {
        println!(
            "resumed {} of {} repeats from {j} (re-ran the rest; bit-identical to the uninterrupted session)",
            session.resumed_repeats, cfg.repeats
        );
    } else if let Some(j) = &cfg.journal_path {
        println!("session journal: {j} ({} repeats checkpointed)", cfg.repeats);
    }
    println!(
        "mean best speedup: {:.2}x over pre-optimized code",
        session.mean_speedup()
    );
    if let Some(db) = &cfg.db_path {
        println!(
            "tuning db {db}: {} cache hits, {} hardware samples across repeats",
            session.total_cache_hits(),
            session.total_samples()
        );
    }
    for c in [18usize, 36, 72, 150] {
        if c <= cfg.budget {
            println!("  speedup@{c:<4} = {:.2}x", session.mean_speedup_at(c));
        }
    }
    if cfg.strategy == Strategy::LlmMcts {
        let model = ModelProfile::by_name(&cfg.model).unwrap();
        println!(
            "LLM: {} calls, {} prompt tokens, ${:.4}, fallback rate {:.2}%",
            session.llm_costs.calls,
            session.llm_costs.prompt_tokens,
            session.llm_costs.usd(&model),
            session.llm_fallback_rate * 100.0
        );
    }
    // Resilience accounting: printed whenever a fault plan is armed (so CI
    // can assert on it) or any failure was absorbed. Stock runs stay
    // byte-identical — this block never fires without injected faults.
    let quarantined = session.total_failed_measurements();
    if faults::armed()
        || quarantined > 0
        || session.llm_costs.retries > 0
        || session.llm_costs.degraded > 0
    {
        println!(
            "fault injection: {} LLM retries, {} degraded calls ({} ms backoff scheduled), {} quarantined measurements",
            session.llm_costs.retries,
            session.llm_costs.degraded,
            session.llm_costs.backoff_ms,
            quarantined
        );
    }
    print!("{}", session.telemetry.render());
    if !args.has_flag("no-record") {
        let reg = Registry::default_location()?;
        let id = reg.record(&session)?;
        println!("recorded run {id} in {}", reg.dir.display());
    }
    // Print the best trace of the first run.
    if let Some(run) = session.runs.first() {
        let base = WorkloadId::from_name(&cfg.workload)
            .ok_or_else(|| anyhow!("unknown workload {}", cfg.workload))?
            .build();
        let sched = Schedule::new(base);
        let (best, _) = sched.apply_all(&run.best_trace);
        println!("\nbest schedule trace (run 0, {:.2}x):", run.best_speedup());
        println!("{}", best.render_trace());
    }
    if let Some(path) = &config_trace {
        export_trace(path)?;
    }
    Ok(())
}

fn cmd_compare(args: &Args) -> Result<()> {
    let base_cfg = config_from(args)?;
    println!(
        "comparing strategies on {} / {} ({} repeats)\n",
        base_cfg.workload, base_cfg.platform, base_cfg.repeats
    );
    println!(
        "{:<22} {:>10} {:>12} {:>12} {:>12}",
        "strategy", "budget", "speedup@36", "speedup@150", "final"
    );
    for strategy in [Strategy::Evolutionary, Strategy::Mcts, Strategy::LlmMcts] {
        let cfg = TuneConfig {
            strategy,
            budget: if strategy == Strategy::Evolutionary {
                base_cfg.budget * 3
            } else {
                base_cfg.budget
            },
            ..base_cfg.clone()
        };
        let s = run_session(&cfg)?;
        println!(
            "{:<22} {:>10} {:>11.2}x {:>11.2}x {:>11.2}x",
            strategy.display(),
            cfg.budget,
            s.mean_speedup_at(36),
            s.mean_speedup_at(150),
            s.mean_speedup()
        );
    }
    Ok(())
}

fn cmd_e2e(args: &Args) -> Result<()> {
    let cfg = config_from(args)?;
    let tasks = workload::llama3_e2e(64);
    println!(
        "end-to-end Llama-3-8B ({} tasks) on {} with {}...",
        tasks.len(),
        cfg.platform,
        cfg.strategy.display()
    );
    let r = run_e2e(&tasks, &cfg)?;
    for (name, session) in &r.tasks {
        println!("  {:<18} {:.2}x", name, session.mean_speedup());
    }
    println!(
        "weighted end-to-end speedup: {:.2}x ({} samples)",
        r.weighted_speedup, r.total_samples
    );
    Ok(())
}

fn cmd_experiment(cmd: &str, args: &Args) -> Result<()> {
    let scale = Scale::from_name(args.opt_or("scale", "default"))
        .ok_or_else(|| anyhow!("bad --scale (smoke|default|full)"))?;
    let seed = args.opt_u64("seed", 42);
    let out_dir = args.opt("out").map(PathBuf::from);
    let run_one = |name: &str| -> (String, String) {
        eprintln!("running {name} at {scale:?} scale...");
        match name {
            "figure3" => {
                let r = figure3::run(scale, seed);
                (r.markdown, r.json.to_pretty())
            }
            "table1" => {
                let r = platforms::table1(scale, seed);
                (r.markdown, r.json.to_pretty())
            }
            "table2" => {
                let r = platforms::table2(scale, seed);
                (r.markdown, r.json.to_pretty())
            }
            "table4" => {
                let r = ablations::table4(scale, seed);
                (r.markdown, r.json.to_pretty())
            }
            "table5" => {
                let r = ablations::table5(scale, seed);
                (r.markdown, r.json.to_pretty())
            }
            "table6" => {
                let r = ablations::table6(scale, seed);
                (r.markdown, r.json.to_pretty())
            }
            "table7" => {
                let r = costs::table7(scale, seed);
                (r.markdown, r.json.to_pretty())
            }
            "table8" => {
                let r = costs::table8(scale, seed);
                (r.markdown, r.json.to_pretty())
            }
            _ => unreachable!(),
        }
    };

    let names: Vec<&str> = if cmd == "all" {
        vec![
            "figure3", "table1", "table2", "table4", "table5", "table6", "table7", "table8",
        ]
    } else {
        vec![cmd]
    };
    for name in names {
        let (md, json) = run_one(name);
        println!("{md}");
        if let Some(dir) = &out_dir {
            std::fs::create_dir_all(dir)?;
            std::fs::write(dir.join(format!("{name}.md")), &md)?;
            std::fs::write(dir.join(format!("{name}.json")), &json)?;
            eprintln!("wrote {}/{name}.{{md,json}}", dir.display());
        }
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let requests = args.opt_usize("requests", 64);
    let config = ServerConfig {
        max_batch: args.opt_usize("max-batch", 8),
        queue_cap: args.opt_usize("queue-cap", 64),
        min_fill: args.opt_usize("min-fill", 1),
        max_wait_ticks: args.opt_u64("max-wait", 4),
        max_queue_ticks: args.opt_u64("max-queue-ticks", 0),
        target_delay_ticks: args.opt_u64("target-delay", 64),
        arrival_burst: args.opt_usize("burst", 2),
        tick_s: 0.0,
    };
    // One persistent executor shared by the serving plane (high-priority
    // execution) and the optional background tuning fleet (low priority):
    // serve traffic preempts tuning at every dequeue and steal site.
    let mut tune_cfg = TuneConfig::default();
    tune_cfg.apply_cli(args);
    let exec = Executor::new(tune_cfg.resolved_workers());
    // Backend: the PJRT runtime over built artifacts when available;
    // otherwise — or with --sim — the simulated backend over the stock
    // workloads, which needs no artifacts and no xla feature.
    let manifest = Manifest::discover();
    let use_sim = args.has_flag("sim") || !cfg!(feature = "xla") || manifest.is_err();
    let (mut server, models) = if use_sim {
        let models: Vec<String> = args
            .opt_or("models", "deepseek_moe,llama4_mlp")
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect();
        println!(
            "serving {} simulated models ({}), {} synthetic requests, max batch {}",
            models.len(),
            models.join(", "),
            requests,
            config.max_batch
        );
        let server =
            Server::start_sim(&models, config)?.with_executor(std::sync::Arc::clone(&exec), 20_000);
        (server, models)
    } else {
        let manifest = manifest?;
        let models: Vec<String> = manifest.artifacts.keys().cloned().collect();
        println!(
            "serving {} artifacts from {} (PJRT CPU), {} synthetic requests, max batch {}",
            manifest.artifacts.len(),
            manifest.dir.display(),
            requests,
            config.max_batch
        );
        (Server::start(&manifest, config)?, models)
    };
    let db_path = PathBuf::from(args.opt_or("db", DEFAULT_DB_PATH));
    // Optionally tune every registered model in the background *while
    // serving*: the fleet shares the serve executor at low priority, so it
    // soaks idle cores but yields to traffic. Records commit to the shared
    // (file-locked) tuning database; schedules re-attach after the join.
    let tune_thread = if args.has_flag("tune") {
        let mut cfg = tune_cfg.clone();
        cfg.budget = args.opt_usize("tune-budget", 40);
        cfg.repeats = args.opt_usize("tune-repeats", 1);
        cfg.db_path = Some(db_path.to_string_lossy().to_string());
        println!(
            "tuning {} registered models in the background ({}-worker shared executor, budget {} x{} repeats)...",
            models.len(),
            cfg.resolved_workers(),
            cfg.budget,
            cfg.repeats
        );
        let tune_models_list = models.clone();
        let tune_exec = std::sync::Arc::clone(&exec);
        Some((
            std::thread::spawn(move || tune_models_on(&tune_models_list, &cfg, &tune_exec)),
            (obs::phase_totals(), obs::exec_counters(), obs::dropped()),
        ))
    } else {
        None
    };
    // Annotate served models with already-recorded schedules up front. A
    // missing db is acceptable when the path is the implicit default or
    // when --tune is about to create it; an explicit --db that doesn't
    // exist otherwise is a user error, not a no-op.
    if args.opt("db").is_some() && !db_path.exists() && tune_thread.is_none() {
        return Err(anyhow!("tuning db {} does not exist", db_path.display()));
    }
    if db_path.exists() {
        let db = Database::open(&db_path)?;
        let matched = server.attach_tuning_db(&db);
        println!(
            "\ntuning db {} ({} records, {matched} served models matched):",
            db_path.display(),
            db.len()
        );
        print!("{}", server.schedule_summary());
    }
    server.run_synthetic(requests, args.opt_u64("seed", 1))?;
    if let Some((handle, (phases0, exec0, dropped0))) = tune_thread {
        let fleet = handle
            .join()
            .map_err(|_| anyhow!("background tuning thread panicked"))??;
        for (model, session) in &fleet.sessions {
            println!(
                "  {:<18} {:.2}x mean speedup ({} samples, {} cache hits)",
                model,
                session.mean_speedup(),
                session.total_samples(),
                session.total_cache_hits()
            );
        }
        // Cross-session dedup summary: one MeasureCache is shared by every
        // session above, so identical program fingerprints are measured at
        // most once per serve session.
        println!(
            "  shared measurement pool: {} fingerprints known, {} evaluations answered without a sample",
            fleet.pool_entries, fleet.pooled_hits
        );
        // Fleet-scoped telemetry (tuning overlapped serving, so the delta
        // covers both — the meaningful unit for a shared executor).
        print!("{}", SessionTelemetry::capture(&phases0, &exec0, dropped0).render());
        // Freshly committed records: re-annotate with the tuned schedules.
        if db_path.exists() {
            let db = Database::open(&db_path)?;
            let matched = server.attach_tuning_db(&db);
            println!("\ntuned schedules attached ({matched} served models matched):");
            print!("{}", server.schedule_summary());
        }
    }
    println!("\n{}", server.metrics.report());
    Ok(())
}

fn cmd_db(args: &Args) -> Result<()> {
    let db_path = PathBuf::from(args.opt_or("db", DEFAULT_DB_PATH));
    let action = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("stats");
    let mut db = Database::open(&db_path)?;
    // A corrupted (or version-drifted) database must be loud, not quietly
    // smaller: every db subcommand leads with the skipped-line count.
    if db.skipped_lines > 0 {
        eprintln!(
            "warning: skipped {} malformed line(s) in {} — corrupted or written by \
             a different version (`db gc` preserves them verbatim)",
            db.skipped_lines,
            db_path.display()
        );
    }
    match action {
        "gc" => {
            let k = args.opt_usize("k", 8);
            let reap = args.has_flag("reap-dominated");
            let report = db.gc_with(k, reap)?;
            // Total from the report, not this handle's pre-gc snapshot:
            // gc re-reads the file and may see other tuners' commits.
            println!(
                "compacted {}: kept {} of {} records, dropped {} \
                 (top-{k} per workload/platform{})",
                db_path.display(),
                report.kept,
                report.kept + report.dropped,
                report.dropped,
                if reap { ", dominated records reaped" } else { "" }
            );
            Ok(())
        }
        "synth" => {
            let n = args.opt_usize("records", 5000);
            let seed = args.opt_u64("seed", 1);
            let platform = args.opt_or("platform", "core_i9");
            let mut rng = Pcg::new(seed);
            let start = db.len();
            for i in 0..n {
                // Power-of-two MoE matmul shapes: one shape class, many
                // distinct workload fingerprints, realistic extent spread.
                let tokens = 1i64 << (2 + rng.gen_range(5));
                let out_dim = 1i64 << (8 + rng.gen_range(7));
                let in_dim = 1i64 << (8 + rng.gen_range(6));
                let prog = workload::moe_matmul("synth", tokens, out_dim, in_dim);
                let factor = 1i64 << (1 + rng.gen_range(4));
                db.add(TuningRecord {
                    workload_fp: workload_fingerprint(&prog),
                    workload: format!("synth_{tokens}x{out_dim}x{in_dim}"),
                    platform: platform.to_string(),
                    strategy: "synth".to_string(),
                    trace: vec![Transform::TileSize { stage: 0, loop_idx: 1, factor }],
                    latency: 0.5 + 9.0 * rng.gen_f64(),
                    baseline_latency: 10.0,
                    seed,
                    timestamp: (start + i) as u64,
                    shape_class: reasoning_compiler::db::shape_class(&prog),
                    extents: reasoning_compiler::transfer::workload_extents(&prog),
                });
            }
            db.commit()?;
            println!(
                "synthesized {n} records into {} ({} total)",
                db_path.display(),
                db.len()
            );
            Ok(())
        }
        "stats" => {
            println!("tuning db {}:", db_path.display());
            println!("{}", db.stats().render());
            Ok(())
        }
        "top" => {
            let workload = args.opt_or("workload", "deepseek_moe");
            let platform = args.opt_or("platform", "core_i9");
            let k = args.opt_usize("k", 10);
            let w = WorkloadId::from_name(workload)
                .ok_or_else(|| anyhow!("unknown workload {workload}"))?;
            let base = w.build();
            let fp = workload_fingerprint(&base);
            let top = db.top_k(fp, platform, k);
            if top.is_empty() {
                println!(
                    "no records for {workload}/{platform} in {} (run `rcc tune` first)",
                    db_path.display()
                );
                return Ok(());
            }
            println!(
                "top {} records for {workload}/{platform} (fingerprint {fp:016x}):",
                top.len()
            );
            println!(
                "{:<16} {:>9} {:>7} {:>6} {:<12}",
                "strategy", "speedup", "trace", "seed", "recorded"
            );
            for r in &top {
                println!(
                    "{:<16} {:>8.2}x {:>7} {:>6} @{}",
                    r.strategy,
                    r.speedup(),
                    r.trace.len(),
                    r.seed,
                    r.timestamp
                );
            }
            // Replay the best trace so `db top` doubles as a health check.
            let best = top[0];
            let sched = Schedule::new(base);
            let (replayed, applied) = sched.apply_all(&best.trace);
            anyhow::ensure!(
                applied == best.trace.len(),
                "best record's trace no longer replays on {workload}"
            );
            println!("\nbest trace:\n{}", replayed.render_trace());
            Ok(())
        }
        other => Err(anyhow!(
            "unknown db action {other:?}; use `db stats`, `db top`, `db gc` or `db synth`"
        )),
    }
}

fn cmd_transfer(args: &Args) -> Result<()> {
    use reasoning_compiler::transfer;

    let db_path = PathBuf::from(args.opt_or("db", DEFAULT_DB_PATH));
    let action = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("match");
    let workload = args.opt_or("workload", "deepseek_moe");
    let platform = args.opt_or("platform", "core_i9");
    let k = args.opt_usize("k", 8);
    let w = WorkloadId::from_name(workload)
        .ok_or_else(|| anyhow!("unknown workload {workload}"))?;
    let base = w.build();
    let mut db = Database::open(&db_path)?;
    // Attach the ANN index unless disabled; retrieval still falls back to
    // the exact scan below the threshold (`transfer::uses_index`).
    if !args.has_flag("no-transfer-index") {
        db.attach_transfer_index(args.opt_usize("transfer-index-threshold", 256));
    }
    let db = db;

    match action {
        "match" => {
            let matches = transfer::find_matches(&db, &base, platform, k);
            println!(
                "retrieval: {}",
                if transfer::uses_index(&db) { "index" } else { "scan" }
            );
            if matches.is_empty() {
                println!(
                    "no structurally similar records for {workload}/{platform} in {} \
                     (tune a same-shape-class workload first)",
                    db_path.display()
                );
                return Ok(());
            }
            println!(
                "{} similar records for {workload}/{platform} (shape class {:016x}):",
                matches.len(),
                reasoning_compiler::db::shape_class(&base)
            );
            println!(
                "{:<18} {:>9} {:>9} {:>7} {:<10} rebase",
                "source workload", "distance", "speedup", "trace", "strategy"
            );
            for m in &matches {
                let rb = transfer::rebase_trace(&base, &m.record.trace);
                println!(
                    "{:<18} {:>9.3} {:>8.2}x {:>7} {:<10} {} kept, {} adjusted, {} dropped{}",
                    m.record.workload,
                    m.distance,
                    m.record.speedup(),
                    m.record.trace.len(),
                    m.record.strategy,
                    rb.trace.len(),
                    rb.adjusted,
                    rb.dropped,
                    if m.superseded { "  [superseded]" } else { "" }
                );
            }
            Ok(())
        }
        "rebase" => {
            let matches = transfer::find_matches(&db, &base, platform, k);
            let Some(best) = matches.first() else {
                println!(
                    "no structurally similar records for {workload}/{platform} in {}",
                    db_path.display()
                );
                return Ok(());
            };
            let rb = transfer::rebase_trace(&base, &best.record.trace);
            println!(
                "rebasing best match ({}, {:.2}x recorded, distance {:.3}) onto {workload}:",
                best.record.workload,
                best.record.speedup(),
                best.distance
            );
            println!(
                "{} of {} steps kept ({} factors rescaled, {} steps dropped)",
                rb.trace.len(),
                best.record.trace.len(),
                rb.adjusted,
                rb.dropped
            );
            let sched = Schedule::new(base);
            let (replayed, applied) = sched.apply_all(&rb.trace);
            anyhow::ensure!(
                applied == rb.trace.len(),
                "rebased trace failed to replay — legality contract violated"
            );
            println!("\nrebased trace (verified legal):\n{}", replayed.render_trace());
            Ok(())
        }
        "exemplars" => {
            let exemplars = transfer::select_exemplars(&db, &base, platform, k);
            if exemplars.is_empty() {
                println!(
                    "no exemplars for {workload}/{platform} in {}",
                    db_path.display()
                );
                return Ok(());
            }
            print!("{}", transfer::render_exemplar_block(&exemplars));
            Ok(())
        }
        other => Err(anyhow!(
            "unknown transfer action {other:?}; use `transfer match`, `transfer rebase` \
             or `transfer exemplars`"
        )),
    }
}

fn cmd_artifacts() -> Result<()> {
    let manifest = Manifest::discover()?;
    let mut rt = reasoning_compiler::runtime::Runtime::cpu()?;
    println!(
        "artifacts in {} (PJRT {}):",
        manifest.dir.display(),
        rt.platform_name()
    );
    let names: Vec<String> = manifest.artifacts.keys().cloned().collect();
    for name in names {
        rt.load(&manifest, &name)?;
        let exe = rt.get(&name).unwrap();
        let out = exe.run(&exe.random_inputs(1))?;
        println!(
            "  {:<18} inputs {:?} -> outputs {:?}  ({:.3} ms)",
            name,
            exe.spec.inputs.iter().map(|t| t.shape.clone()).collect::<Vec<_>>(),
            exe.spec.outputs.iter().map(|t| t.shape.clone()).collect::<Vec<_>>(),
            out.latency_s * 1e3
        );
    }
    Ok(())
}

fn cmd_show(args: &Args) -> Result<()> {
    let name = args.opt_or("workload", "deepseek_moe");
    let w = WorkloadId::from_name(name).ok_or_else(|| anyhow!("unknown workload {name}"))?;
    let p = w.build();
    println!("{}", printer::print_program(&p));
    let plat = Platform::by_name(args.opt_or("platform", "core_i9")).unwrap();
    println!("--- cost model analysis ({}) ---", plat.display);
    println!("{}", features::extract(&p, &plat).render());
    Ok(())
}

fn cmd_prompt(args: &Args) -> Result<()> {
    use reasoning_compiler::reasoning::engine::LlmEngine;
    let name = args.opt_or("workload", "deepseek_moe");
    let w = WorkloadId::from_name(name).ok_or_else(|| anyhow!("unknown workload {name}"))?;
    let plat = Platform::by_name(args.opt_or("platform", "core_i9")).unwrap();
    let base = Schedule::new(w.build());
    let child = {
        let mut rng = reasoning_compiler::util::Pcg::new(args.opt_u64("seed", 1));
        let analysis = reasoning_compiler::cost::AnalysisCache::new();
        let (seq, _) = reasoning::engine::informed_proposals(
            &base,
            &plat,
            &Default::default(),
            &analysis,
            &mut rng,
        );
        base.apply_all(&seq).0
    };
    // With a tuning database present, similar-workload exemplars appear in
    // the prompt exactly as a transfer-enabled tuning session would see.
    let exemplars = {
        let db_path = PathBuf::from(args.opt_or("db", DEFAULT_DB_PATH));
        if db_path.exists() {
            let db = Database::open(&db_path)?;
            reasoning_compiler::transfer::select_exemplars(&db, &base.current, plat.name, 4)
        } else {
            Vec::new()
        }
    };
    let ctx = PromptContext {
        node: &child,
        ancestors: vec![&base],
        scores: vec![0.773, 0.313],
        platform: &plat,
        exemplars: &exemplars,
    };
    println!("=== PROMPT ===\n{}", reasoning::prompt::render(&ctx));
    let model = ModelProfile::by_name(args.opt_or("model", "gpt4o_mini"))
        .ok_or_else(|| anyhow!("bad model"))?;
    let mut engine = reasoning::SimulatedLlm::new(model, args.opt_u64("seed", 1));
    let response = engine.complete(&ctx);
    println!("=== RESPONSE ===\n{}", response.text);
    Ok(())
}

fn cmd_history() -> Result<()> {
    let reg = Registry::default_location()?;
    let records = reg.list()?;
    if records.is_empty() {
        println!("no recorded runs in {} (run `rcc tune` first)", reg.dir.display());
        return Ok(());
    }
    println!(
        "{:<14} {:<18} {:<12} {:>10} {:>9} {:>8}",
        "strategy", "workload", "platform", "mean", "best", "samples"
    );
    for r in records {
        println!(
            "{:<14} {:<18} {:<12} {:>9.2}x {:>8.2}x {:>8}",
            r.strategy, r.workload, r.platform, r.mean_speedup, r.best_speedup, r.samples
        );
    }
    Ok(())
}

fn cmd_best(args: &Args) -> Result<()> {
    let workload = args.opt_or("workload", "deepseek_moe");
    let platform = args.opt_or("platform", "core_i9");
    let reg = Registry::default_location()?;
    let Some(r) = reg.best_for(workload, platform)? else {
        return Err(anyhow!("no recorded run for {workload}/{platform}"));
    };
    println!(
        "best recorded run {}: {:.2}x via {} ({} samples)",
        r.id, r.best_speedup, r.strategy, r.samples
    );
    let base = WorkloadId::from_name(workload)
        .ok_or_else(|| anyhow!("unknown workload"))?
        .build();
    let (best, applied) = Schedule::new(base).apply_all(&r.best_trace);
    anyhow::ensure!(applied == r.best_trace.len(), "persisted trace no longer replays");
    println!("\ntrace:\n{}", best.render_trace());
    println!("\nscheduled TIR:\n{}", printer::print_program(&best.current));
    Ok(())
}
