//! # REASONING COMPILER
//!
//! Reproduction of *"REASONING COMPILER: LLM-Guided Optimizations for
//! Efficient Model Serving"* (NeurIPS 2025) as a three-layer
//! Rust + JAX + Pallas stack.
//!
//! The paper casts tensor-program schedule optimization as a finite-horizon
//! MDP searched by MCTS, where node expansion is proposed by an LLM that
//! reasons over the program, its transformation history and cost-model
//! feedback. This crate provides:
//!
//! - [`tir`] — a tensor-program IR (the MetaSchedule substrate): loop nests,
//!   compute blocks, the five paper workloads, a printer and an interpreter.
//! - [`schedule`] — transformation primitives (`TileSize`, `Reorder`,
//!   `Fuse`, `Parallel`, `Vectorize`, `Unroll`, `ComputeLocation`,
//!   `CacheWrite`), traces, legality and random sampling.
//! - [`cost`] — feature extraction, the analytical rollout surrogate f-hat
//!   and the per-platform hardware simulator f.
//! - [`search`] — MCTS with UCT (serial or leaf-parallel with virtual
//!   loss) and the TVM-style Evolutionary Search baseline, unified behind
//!   the `SearchStrategy` trait, both warm-startable from the tuning
//!   database and evaluated through a batched measurement pipeline backed
//!   by the measurement cache and the crate-wide persistent work-stealing
//!   executor (`util::executor`).
//! - [`reasoning`] — the paper's contribution: prompt construction,
//!   proposal parsing/validation with fallback, simulated LLM model
//!   profiles and API cost tracking.
//! - [`db`] — the persistent tuning-record database: structural workload/
//!   program fingerprints, JSONL tuning records with provenance, the
//!   measurement cache, and warm-start hints derived from past runs.
//! - [`transfer`] — cross-workload transfer tuning: shape-class
//!   similarity matching over the database (exact scan or, at scale, an
//!   HNSW-style ANN index persisted as a `<db>.idx` sidecar, with
//!   record aging), a trace rebaser that replays recorded traces onto
//!   differently-sized workloads, and the bottleneck-conditioned
//!   few-shot exemplar engine feeding accumulated feedback into LLM
//!   prompts.
//! - [`coordinator`] — tuning sessions, config system, serving loop.
//! - [`obs`] — the observability plane: a lock-cheap span/event recorder
//!   with stable event kinds across search, batch evaluation, LLM calls,
//!   db maintenance and serving, always-on executor/phase counters, and
//!   Chrome trace-event (Perfetto) + human-summary exporters. Recording
//!   never influences seeds, fold order or results.
//! - [`runtime`] — PJRT execution of the AOT artifacts produced by the
//!   Python build path (`python/compile/aot.py`).
//! - [`report`] — regenerators for every table and figure in the paper.

pub mod util;
pub mod tir;
pub mod schedule;
pub mod cost;
pub mod search;
pub mod reasoning;
pub mod db;
pub mod transfer;
pub mod coordinator;
pub mod obs;
pub mod runtime;
pub mod report;
