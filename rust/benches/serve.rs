//! Serving-plane load generator (`BENCH_serve.json`).
//!
//! `cargo bench --bench serve` (`RCC_BENCH_QUICK=1` for the CI smoke).
//!
//! Open-loop seeded arrivals against the simulated backend, execution
//! fanned onto the persistent executor as high-priority tasks:
//!
//! - `serve_scale_w{N}`: batch throughput scaling from workers=1 up —
//!   identical scheduling decisions (asserted bit-exact), only wall
//!   clock moves;
//! - `serve_p99_tune_idle` / `serve_p99_tune_saturated`: wall-clock p99
//!   with the executor quiet vs flooded by low-priority background work
//!   (a stand-in for `rcc serve --tune`). High-priority serve dispatch
//!   preempts the flood at every dequeue/steal site, so the ratio
//!   staying near 1x (target: within 2x) is the no-priority-inversion
//!   acceptance number;
//! - `serve_overload`: rejection accounting under saturating bursts
//!   against tiny admission budgets.
//!
//! Set `RCC_BENCH_SERVE_JSON` to change the output path.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use reasoning_compiler::coordinator::server::synthetic_work;
use reasoning_compiler::coordinator::{Server, ServerConfig};
use reasoning_compiler::util::executor::{Executor, Priority};
use reasoning_compiler::util::json::{arr, num, s, Json};
use reasoning_compiler::util::stats::percentile;

const SPIN_PER_TICK: u64 = 20_000;
const LOAD_SEED: u64 = 9;

fn models() -> Vec<String> {
    vec!["deepseek_moe".to_string(), "llama4_mlp".to_string()]
}

struct RunOutcome {
    served: u64,
    rejected: u64,
    wall_s: f64,
    virt_p50_ms: f64,
    virt_p99_ms: f64,
    wall_p99_ms: f64,
    /// Deterministic digest of every scheduling decision.
    digest: Vec<(String, u64, u64, u64, u64, u64, u64, Vec<u64>)>,
}

fn run_load(workers: usize, requests: usize, config: ServerConfig, flooded: bool) -> RunOutcome {
    let exec = Executor::new(workers);
    let stop = Arc::new(AtomicBool::new(false));
    let flood = flooded.then(|| {
        let fe = Arc::clone(&exec);
        let fs = Arc::clone(&stop);
        std::thread::spawn(move || {
            while !fs.load(Ordering::Relaxed) {
                let tasks: Vec<_> = (0..32).map(|_| || synthetic_work(50_000)).collect();
                fe.run_with(Priority::Low, tasks);
            }
        })
    });
    let mut server = Server::start_sim(&models(), config)
        .unwrap()
        .with_executor(Arc::clone(&exec), SPIN_PER_TICK);
    let t0 = Instant::now();
    server.run_synthetic(requests, LOAD_SEED).unwrap();
    let wall_s = t0.elapsed().as_secs_f64();
    stop.store(true, Ordering::Relaxed);
    if let Some(h) = flood {
        h.join().unwrap();
    }
    let mut virt: Vec<f64> = Vec::new();
    let mut wall: Vec<f64> = Vec::new();
    for m in server.metrics.per_model.values() {
        virt.extend_from_slice(m.request_latencies.samples());
        wall.extend_from_slice(m.wall_latencies.samples());
    }
    RunOutcome {
        served: server.metrics.total_requests(),
        rejected: server.metrics.total_rejected(),
        wall_s,
        virt_p50_ms: percentile(&virt, 50.0) * 1e3,
        virt_p99_ms: percentile(&virt, 99.0) * 1e3,
        wall_p99_ms: percentile(&wall, 99.0) * 1e3,
        digest: server
            .metrics
            .per_model
            .iter()
            .map(|(name, m)| {
                (
                    name.clone(),
                    m.admitted,
                    m.rejected,
                    m.evicted,
                    m.requests,
                    m.batches,
                    m.partial_dispatches,
                    m.request_latencies.samples().iter().map(|v| v.to_bits()).collect(),
                )
            })
            .collect(),
    }
}

fn entry(name: &str, o: &RunOutcome) -> Json {
    let mut e = Json::obj();
    e.set("name", s(name))
        .set("served", num(o.served as f64))
        .set("rejected", num(o.rejected as f64))
        .set("wall_ms", num(o.wall_s * 1e3))
        .set("throughput_rps", num(o.served as f64 / o.wall_s.max(1e-9)))
        .set("virt_p50_ms", num(o.virt_p50_ms))
        .set("virt_p99_ms", num(o.virt_p99_ms))
        .set("wall_p99_ms", num(o.wall_p99_ms));
    e
}

fn main() {
    let quick = std::env::var_os("RCC_BENCH_QUICK").is_some();
    let requests = if quick { 300 } else { 2000 };
    let worker_counts: &[usize] = if quick { &[1, 4] } else { &[1, 2, 4] };
    let mut entries: Vec<Json> = Vec::new();

    // --- throughput scaling with workers --------------------------------
    println!("== serve: throughput scaling ({requests} requests) ==");
    let mut scale_runs: Vec<(usize, RunOutcome)> = Vec::new();
    for &w in worker_counts {
        let o = run_load(w, requests, ServerConfig::default(), false);
        println!(
            "  workers={w}: {:.0} req/s ({} served, {} rejected, wall {:.1} ms, virt p99 {:.3} ms)",
            o.served as f64 / o.wall_s.max(1e-9),
            o.served,
            o.rejected,
            o.wall_s * 1e3,
            o.virt_p99_ms
        );
        entries.push(entry(&format!("serve_scale_w{w}"), &o));
        scale_runs.push((w, o));
    }
    // Standing contract: worker count moves wall clock only, never a
    // scheduling decision. A digest mismatch is a determinism regression.
    for (w, o) in &scale_runs[1..] {
        assert_eq!(
            scale_runs[0].1.digest, o.digest,
            "scheduling decisions differ between workers=1 and workers={w}"
        );
    }

    // --- priority inversion under a saturating tuning load --------------
    println!("\n== serve: saturating low-priority background load (workers=4) ==");
    let idle = run_load(4, requests, ServerConfig::default(), false);
    let saturated = run_load(4, requests, ServerConfig::default(), true);
    assert_eq!(
        idle.digest, saturated.digest,
        "background load must not change scheduling decisions"
    );
    let ratio = saturated.wall_p99_ms / idle.wall_p99_ms.max(1e-9);
    println!("  tune-idle      wall p99: {:.3} ms", idle.wall_p99_ms);
    println!("  tune-saturated wall p99: {:.3} ms", saturated.wall_p99_ms);
    println!(
        "  ratio: {ratio:.2}x (target <= 2x, no priority inversion) — {}",
        if ratio <= 2.0 { "PASS" } else { "OVER" }
    );
    entries.push(entry("serve_p99_tune_idle", &idle));
    entries.push(entry("serve_p99_tune_saturated", &saturated));
    let mut r = Json::obj();
    r.set("name", s("serve_p99_saturated_over_idle")).set("value", num(ratio));
    entries.push(r);

    // --- overload: tiny budgets, aggressive bursts ----------------------
    println!("\n== serve: overload (queue_cap=2, burst=6) ==");
    let overload_cfg = ServerConfig { queue_cap: 2, arrival_burst: 6, ..Default::default() };
    let o = run_load(2, requests, overload_cfg, false);
    println!(
        "  {} served, {} rejected ({:.0}% shed), virt p99 {:.3} ms",
        o.served,
        o.rejected,
        100.0 * o.rejected as f64 / (o.served + o.rejected).max(1) as f64,
        o.virt_p99_ms
    );
    assert!(o.rejected > 0, "saturating bursts must trip admission control");
    entries.push(entry("serve_overload", &o));

    let path = std::env::var("RCC_BENCH_SERVE_JSON")
        .unwrap_or_else(|_| "BENCH_serve.json".to_string());
    match std::fs::write(&path, arr(entries).to_pretty() + "\n") {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }
}
