//! Bench: regenerate the paper's table5 (see DESIGN.md per-experiment index).
//!
//! `cargo bench --bench table5_trace_depth` — set RC_SCALE=smoke|default|full.

use reasoning_compiler::report::{ablations, Scale};
use std::time::Instant;

fn main() {
    let scale = std::env::var("RC_SCALE")
        .ok()
        .and_then(|s| Scale::from_name(&s))
        .unwrap_or(Scale::Default);
    let t0 = Instant::now();
    let r = ablations::table5(scale, 42);
    println!("{}", r.markdown);
    eprintln!("[bench] table5 regenerated in {:.1}s", t0.elapsed().as_secs_f64());
}
