//! Bench: regenerate the paper's table2 (see DESIGN.md per-experiment index).
//!
//! `cargo bench --bench table2_end_to_end` — set RC_SCALE=smoke|default|full.

use reasoning_compiler::report::{platforms, Scale};
use std::time::Instant;

fn main() {
    let scale = std::env::var("RC_SCALE")
        .ok()
        .and_then(|s| Scale::from_name(&s))
        .unwrap_or(Scale::Default);
    let t0 = Instant::now();
    let r = platforms::table2(scale, 42);
    println!("{}", r.markdown);
    eprintln!("[bench] table2 regenerated in {:.1}s", t0.elapsed().as_secs_f64());
}
