//! Design-choice ablations called out in DESIGN.md (beyond the paper's own
//! ablations): what does each architectural decision buy?
//!
//! `cargo bench --bench ablation_design`
//!
//! 1. **Rollout signal** — surrogate f̂ (the paper's design) vs using the
//!    hardware model as rollout oracle vs no rollout at all
//!    (`rollout_len = 0`): quantifies how much the cheap-but-noisy
//!    surrogate actually costs in final quality.
//! 2. **Exploration constant** — UCT c in {0.5, sqrt2, 4}.
//! 3. **Proposal sequence length** — capping LLM proposals at 1 vs 3.

use reasoning_compiler::cost::{HardwareModel, Platform, SurrogateModel};
use reasoning_compiler::reasoning::{LlmPolicy, ModelProfile, SimulatedLlm};
use reasoning_compiler::search::{mcts_search, MctsConfig};
use reasoning_compiler::tir::WorkloadId;
use reasoning_compiler::util::stats;

fn rc_run(cfg: &MctsConfig, use_surrogate: bool, budget: usize, seed: u64) -> f64 {
    let plat = Platform::core_i9();
    let base = WorkloadId::DeepSeekMoe.build();
    let hardware = HardwareModel::new(plat.clone());
    let surrogate = SurrogateModel::new(plat.clone());
    let engine = SimulatedLlm::new(ModelProfile::gpt4o_mini(), seed);
    let mut policy = LlmPolicy::new(engine, cfg.history_depth, seed);
    let r = if use_surrogate {
        mcts_search(&base, &mut policy, &surrogate, &hardware, cfg, &plat, budget, seed)
    } else {
        mcts_search(&base, &mut policy, &hardware, &hardware, cfg, &plat, budget, seed)
    };
    r.best_speedup()
}

fn mean_over_seeds(f: impl Fn(u64) -> f64) -> f64 {
    stats::mean(&(1..=5u64).map(f).collect::<Vec<_>>())
}

fn main() {
    let budget = 100;
    println!("== design-choice ablations (deepseek_moe / core_i9, budget {budget}, 5 seeds) ==\n");

    println!("--- rollout signal ---");
    let base_cfg = MctsConfig::default();
    let with_surrogate = mean_over_seeds(|s| rc_run(&base_cfg, true, budget, s));
    let with_oracle = mean_over_seeds(|s| rc_run(&base_cfg, false, budget, s));
    let no_rollout_cfg = MctsConfig { rollout_len: 0, ..Default::default() };
    let no_rollout = mean_over_seeds(|s| rc_run(&no_rollout_cfg, true, budget, s));
    println!("surrogate rollouts (paper design): {with_surrogate:.2}x");
    println!("hardware-oracle rollouts:          {with_oracle:.2}x");
    println!("no rollouts (child score only):    {no_rollout:.2}x");

    println!("\n--- UCT exploration constant ---");
    for c in [0.5, std::f64::consts::SQRT_2, 4.0] {
        let cfg = MctsConfig { exploration_c: c, ..Default::default() };
        let v = mean_over_seeds(|s| rc_run(&cfg, true, budget, s));
        println!("c = {c:<8.3} -> {v:.2}x");
    }

    println!("\n--- max trace length (horizon T) ---");
    for t in [8usize, 16, 24, 32] {
        let cfg = MctsConfig { max_trace_len: t, ..Default::default() };
        let v = mean_over_seeds(|s| rc_run(&cfg, true, budget, s));
        println!("T = {t:<4} -> {v:.2}x");
    }
}
