//! Transfer-tuning sample-efficiency benchmark (PR 4 acceptance).
//!
//! `cargo bench --bench transfer`
//!
//! Measures the paper's headline quantity — hardware samples to reach a
//! target speedup — cold vs transfer-warm: tune workload A (the prior
//! work), then search a structurally similar workload B twice, once cold
//! and once with `transfer` rebasing A's records into warm starts. Writes
//! `BENCH_transfer.json` (`name`, `samples_to_target`, `best_speedup`,
//! plus a `sample_reduction` summary entry) for cross-PR tracking.
//!
//! Since PR 7 it also measures the ANN transfer index at scale: for
//! synthetic databases of growing size it reports index build time,
//! per-query time scan vs index, the speedup, and the index's recall of
//! the scan's exact top-k (`index_scale_*` entries).
//!
//! `RCC_BENCH_QUICK=1` shrinks budgets and database sizes for CI smoke;
//! `RCC_BENCH_TRANSFER_JSON` overrides the output path.

use std::time::Instant;

use reasoning_compiler::coordinator::{run_session_on, Strategy, TuneConfig};
use reasoning_compiler::db::{shape_class, workload_fingerprint, Database, TuningRecord};
use reasoning_compiler::schedule::Transform;
use reasoning_compiler::tir::workload;
use reasoning_compiler::transfer::{find_matches, uses_index, workload_extents};
use reasoning_compiler::util::json::{arr, num, s, Json};
use reasoning_compiler::util::Pcg;

/// Random MoE-matmul dims (power-of-two): one shape class, many
/// distinct workload fingerprints.
fn random_dims(rng: &mut Pcg) -> (i64, i64, i64) {
    (
        1i64 << (2 + rng.gen_range(5)),
        1i64 << (8 + rng.gen_range(7)),
        1i64 << (8 + rng.gen_range(6)),
    )
}

/// Scan-vs-index retrieval at growing database sizes. Returns one JSON
/// entry per size with build/query times, speedup and recall.
fn index_scale_series(quick: bool) -> Vec<Json> {
    const K: usize = 8;
    const QUERIES: usize = 32;
    let sizes: &[usize] = if quick { &[1_000, 5_000] } else { &[1_000, 10_000, 100_000] };

    let mut out = Vec::new();
    println!("\n== index scale series (k = {K}, {QUERIES} queries per size) ==");
    for &n in sizes {
        // Synthetic corpus: real shape class + extents, per-shape
        // fingerprints, random latencies, sequential timestamps.
        let mut rng = Pcg::new(7);
        let mut scan_db = Database::in_memory();
        for i in 0..n {
            let (t, o, i_dim) = random_dims(&mut rng);
            let prog = workload::moe_matmul("scale_src", t, o, i_dim);
            scan_db.add(TuningRecord {
                workload_fp: workload_fingerprint(&prog),
                workload: format!("scale_{t}x{o}x{i_dim}"),
                platform: "core_i9".to_string(),
                strategy: "synth".to_string(),
                trace: vec![Transform::TileSize { stage: 0, loop_idx: 1, factor: 4 }],
                latency: 0.5 + 9.0 * rng.gen_f64(),
                baseline_latency: 10.0,
                seed: 7,
                timestamp: i as u64,
                shape_class: shape_class(&prog),
                extents: workload_extents(&prog),
            });
        }
        let mut ix_db = scan_db.clone();

        // Query workloads drawn from the same shape distribution (their
        // own fingerprints are excluded from matching, like a real tune).
        let mut qrng = Pcg::new(0xBEEF);
        let queries: Vec<_> = (0..QUERIES)
            .map(|_| {
                let (t, o, i_dim) = random_dims(&mut qrng);
                workload::moe_matmul("scale_query", t, o, i_dim)
            })
            .collect();

        let t0 = Instant::now();
        let scan_results: Vec<Vec<(u64, u64)>> = queries
            .iter()
            .map(|q| {
                find_matches(&scan_db, q, "core_i9", K)
                    .iter()
                    .map(|m| (m.record.workload_fp, m.record.timestamp))
                    .collect()
            })
            .collect();
        let scan_ms = t0.elapsed().as_secs_f64() * 1e3;

        let t0 = Instant::now();
        ix_db.attach_transfer_index(0);
        let build_ms = t0.elapsed().as_secs_f64() * 1e3;
        assert!(uses_index(&ix_db), "index must engage at threshold 0");

        let t0 = Instant::now();
        let ix_results: Vec<Vec<(u64, u64)>> = queries
            .iter()
            .map(|q| {
                find_matches(&ix_db, q, "core_i9", K)
                    .iter()
                    .map(|m| (m.record.workload_fp, m.record.timestamp))
                    .collect()
            })
            .collect();
        let index_ms = t0.elapsed().as_secs_f64() * 1e3;

        // Recall: fraction of the scan's exact top-k the index returned.
        let (mut hit, mut want) = (0usize, 0usize);
        for (exact, approx) in scan_results.iter().zip(&ix_results) {
            want += exact.len();
            hit += exact.iter().filter(|e| approx.contains(e)).count();
        }
        let recall = if want == 0 { 1.0 } else { hit as f64 / want as f64 };
        let speedup = scan_ms / index_ms.max(1e-9);
        println!(
            "{n:>7} records: build {build_ms:>8.1} ms, scan {:>8.3} ms/q, \
             index {:>8.3} ms/q — {speedup:>6.1}x, recall {recall:.3}",
            scan_ms / QUERIES as f64,
            index_ms / QUERIES as f64,
        );

        let mut o = Json::obj();
        o.set("name", s(&format!("index_scale_{n}")))
            .set("records", num(n as f64))
            .set("build_ms", num(build_ms))
            .set("scan_query_ms", num(scan_ms / QUERIES as f64))
            .set("index_query_ms", num(index_ms / QUERIES as f64))
            .set("speedup_vs_scan", num(speedup))
            .set("recall", num(recall));
        out.push(o);
    }
    out
}

fn main() {
    let quick = std::env::var_os("RCC_BENCH_QUICK").is_some();
    let (budget_a, budget_b) = if quick { (60, 50) } else { (150, 120) };

    let db_path = std::env::temp_dir().join(format!(
        "rcc_bench_transfer_{}_{}.jsonl",
        std::process::id(),
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos()
    ));
    let db_str = db_path.to_string_lossy().to_string();

    let a = workload::moe_matmul("transfer_bench_src", 32, 512, 256);
    let b = workload::moe_matmul("transfer_bench_dst", 16, 256, 128);

    // ---- prior work: LLM-guided tuning of A into the database -----------
    let cfg_a = TuneConfig {
        strategy: Strategy::LlmMcts,
        budget: budget_a,
        repeats: 2,
        seed: 42,
        db_path: Some(db_str.clone()),
        workers: 1,
        ..Default::default()
    };
    let sess_a = run_session_on(&a, &cfg_a).expect("tune A");
    println!(
        "prior work: tuned {} to {:.2}x mean ({} samples)",
        a.name,
        sess_a.mean_speedup(),
        sess_a.total_samples()
    );

    // ---- cold vs transfer-warm on B -------------------------------------
    let cfg_cold = TuneConfig {
        strategy: Strategy::Mcts,
        budget: budget_b,
        repeats: 1,
        seed: 7,
        db_path: None,
        workers: 1,
        ..Default::default()
    };
    let cold = run_session_on(&b, &cfg_cold).expect("cold B");
    let cold_run = &cold.runs[0];
    let target = cold_run.best_speedup();
    let cold_samples = cold_run.samples_to_reach(target).unwrap_or(cold_run.samples_used);

    let cfg_warm = TuneConfig { db_path: Some(db_str), ..cfg_cold };
    let warm = run_session_on(&b, &cfg_warm).expect("transfer-warm B");
    let warm_run = &warm.runs[0];
    let warm_samples = warm_run.samples_to_reach(target);

    println!("\n== transfer sample efficiency (target {target:.2}x, cold best) ==");
    println!(
        "cold:          best {:.2}x, {} samples to target (budget {})",
        cold_run.best_speedup(),
        cold_samples,
        budget_b
    );
    match warm_samples {
        Some(n) => println!(
            "transfer-warm: best {:.2}x, {} samples to target — {:.1}% of cold{}",
            warm_run.best_speedup(),
            n,
            100.0 * n as f64 / cold_samples.max(1) as f64,
            if n * 2 <= cold_samples { " (PASS <= 50%)" } else { " (BELOW TARGET)" }
        ),
        None => println!(
            "transfer-warm: best {:.2}x — never reached the cold target (FAIL)",
            warm_run.best_speedup()
        ),
    }

    // ---- machine-readable output ----------------------------------------
    let entry = |name: &str, samples: f64, best: f64| {
        let mut o = Json::obj();
        o.set("name", s(name))
            .set("samples_to_target", num(samples))
            .set("best_speedup", num(best));
        o
    };
    let mut summary = Json::obj();
    summary.set("name", s("sample_reduction_ratio")).set(
        "value",
        num(warm_samples.map_or(-1.0, |n| n as f64 / cold_samples.max(1) as f64)),
    );
    let mut entries = vec![
        entry("cold", cold_samples as f64, cold_run.best_speedup()),
        entry(
            "transfer_warm",
            warm_samples.map_or(-1.0, |n| n as f64),
            warm_run.best_speedup(),
        ),
        summary,
    ];
    entries.extend(index_scale_series(quick));
    let doc = arr(entries);
    let out_path = std::env::var("RCC_BENCH_TRANSFER_JSON")
        .unwrap_or_else(|_| "BENCH_transfer.json".to_string());
    match std::fs::write(&out_path, doc.to_pretty() + "\n") {
        Ok(()) => println!("\nwrote {out_path}"),
        Err(e) => eprintln!("\nfailed to write {out_path}: {e}"),
    }
    std::fs::remove_file(&db_path).ok();
}
