//! Transfer-tuning sample-efficiency benchmark (PR 4 acceptance).
//!
//! `cargo bench --bench transfer`
//!
//! Measures the paper's headline quantity — hardware samples to reach a
//! target speedup — cold vs transfer-warm: tune workload A (the prior
//! work), then search a structurally similar workload B twice, once cold
//! and once with `transfer` rebasing A's records into warm starts. Writes
//! `BENCH_transfer.json` (`name`, `samples_to_target`, `best_speedup`,
//! plus a `sample_reduction` summary entry) for cross-PR tracking.
//! `RCC_BENCH_QUICK=1` shrinks budgets for CI smoke;
//! `RCC_BENCH_TRANSFER_JSON` overrides the output path.

use reasoning_compiler::coordinator::{run_session_on, Strategy, TuneConfig};
use reasoning_compiler::tir::workload;
use reasoning_compiler::util::json::{arr, num, s, Json};

fn main() {
    let quick = std::env::var_os("RCC_BENCH_QUICK").is_some();
    let (budget_a, budget_b) = if quick { (60, 50) } else { (150, 120) };

    let db_path = std::env::temp_dir().join(format!(
        "rcc_bench_transfer_{}_{}.jsonl",
        std::process::id(),
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos()
    ));
    let db_str = db_path.to_string_lossy().to_string();

    let a = workload::moe_matmul("transfer_bench_src", 32, 512, 256);
    let b = workload::moe_matmul("transfer_bench_dst", 16, 256, 128);

    // ---- prior work: LLM-guided tuning of A into the database -----------
    let cfg_a = TuneConfig {
        strategy: Strategy::LlmMcts,
        budget: budget_a,
        repeats: 2,
        seed: 42,
        db_path: Some(db_str.clone()),
        workers: 1,
        ..Default::default()
    };
    let sess_a = run_session_on(&a, &cfg_a).expect("tune A");
    println!(
        "prior work: tuned {} to {:.2}x mean ({} samples)",
        a.name,
        sess_a.mean_speedup(),
        sess_a.total_samples()
    );

    // ---- cold vs transfer-warm on B -------------------------------------
    let cfg_cold = TuneConfig {
        strategy: Strategy::Mcts,
        budget: budget_b,
        repeats: 1,
        seed: 7,
        db_path: None,
        workers: 1,
        ..Default::default()
    };
    let cold = run_session_on(&b, &cfg_cold).expect("cold B");
    let cold_run = &cold.runs[0];
    let target = cold_run.best_speedup();
    let cold_samples = cold_run.samples_to_reach(target).unwrap_or(cold_run.samples_used);

    let cfg_warm = TuneConfig { db_path: Some(db_str), ..cfg_cold };
    let warm = run_session_on(&b, &cfg_warm).expect("transfer-warm B");
    let warm_run = &warm.runs[0];
    let warm_samples = warm_run.samples_to_reach(target);

    println!("\n== transfer sample efficiency (target {target:.2}x, cold best) ==");
    println!(
        "cold:          best {:.2}x, {} samples to target (budget {})",
        cold_run.best_speedup(),
        cold_samples,
        budget_b
    );
    match warm_samples {
        Some(n) => println!(
            "transfer-warm: best {:.2}x, {} samples to target — {:.1}% of cold{}",
            warm_run.best_speedup(),
            n,
            100.0 * n as f64 / cold_samples.max(1) as f64,
            if n * 2 <= cold_samples { " (PASS <= 50%)" } else { " (BELOW TARGET)" }
        ),
        None => println!(
            "transfer-warm: best {:.2}x — never reached the cold target (FAIL)",
            warm_run.best_speedup()
        ),
    }

    // ---- machine-readable output ----------------------------------------
    let entry = |name: &str, samples: f64, best: f64| {
        let mut o = Json::obj();
        o.set("name", s(name))
            .set("samples_to_target", num(samples))
            .set("best_speedup", num(best));
        o
    };
    let mut summary = Json::obj();
    summary.set("name", s("sample_reduction_ratio")).set(
        "value",
        num(warm_samples.map_or(-1.0, |n| n as f64 / cold_samples.max(1) as f64)),
    );
    let doc = arr(vec![
        entry("cold", cold_samples as f64, cold_run.best_speedup()),
        entry(
            "transfer_warm",
            warm_samples.map_or(-1.0, |n| n as f64),
            warm_run.best_speedup(),
        ),
        summary,
    ]);
    let out_path = std::env::var("RCC_BENCH_TRANSFER_JSON")
        .unwrap_or_else(|_| "BENCH_transfer.json".to_string());
    match std::fs::write(&out_path, doc.to_pretty() + "\n") {
        Ok(()) => println!("\nwrote {out_path}"),
        Err(e) => eprintln!("\nfailed to write {out_path}: {e}"),
    }
    std::fs::remove_file(&db_path).ok();
}
