//! Micro-benchmarks of the search hot paths (EXPERIMENTS.md §Perf).
//!
//! `cargo bench --bench micro_hotpaths`
//!
//! These are the operations executed thousands of times per tuning run:
//! access analysis, simulator/surrogate evaluation, transform application,
//! legal-action enumeration, prompt rendering and a full simulated-LLM
//! proposal round. The §Perf target: simulator eval >50k/s so a full
//! Table-1 sweep stays in minutes.
//!
//! Besides the human-readable report, the run writes a machine-readable
//! `BENCH_micro_hotpaths.json` (per-bench `name`, `median_ns`,
//! `throughput_per_s`) so the perf trajectory is tracked across PRs.
//! Set `RCC_BENCH_JSON` to change the output path and `RCC_BENCH_QUICK=1`
//! for a fast CI smoke run.

use reasoning_compiler::cost::{
    access, analytical, latency_batch, simulator, CostModel, HardwareModel, LatencyJob, Platform,
};
use reasoning_compiler::db::{program_fingerprint, workload_fingerprint, MeasureCache};
use reasoning_compiler::obs;
use reasoning_compiler::reasoning::{prompt::PromptContext, ModelProfile, SimulatedLlm};
use reasoning_compiler::schedule::{sampler, Schedule, Transform};
use reasoning_compiler::tir::WorkloadId;
use reasoning_compiler::util::bench::{BenchResult, Bencher};
use reasoning_compiler::util::executor::Executor;
use reasoning_compiler::util::json::{arr, num, s, Json};
use reasoning_compiler::util::rng::Pcg;

/// Dump all results as a JSON array for cross-PR perf tracking.
fn write_json(results: &[BenchResult], tracing_overhead_pct: f64) {
    let path = std::env::var("RCC_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_micro_hotpaths.json".to_string());
    let mut entries: Vec<Json> = results
        .iter()
        .map(|r| {
            let mut o = Json::obj();
            o.set("name", s(&r.name))
                .set("median_ns", num(r.median_ns))
                .set("throughput_per_s", num(r.throughput_per_s));
            o
        })
        .collect();
    // Scalar acceptance number from the PR-6 observability work, kept in
    // the same array so the artifact format stays a flat list of names.
    let mut o = Json::obj();
    o.set("name", s("tracing_overhead_pct")).set("value", num(tracing_overhead_pct));
    entries.push(o);
    match std::fs::write(&path, arr(entries).to_pretty() + "\n") {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }
}

fn main() {
    let b = if std::env::var_os("RCC_BENCH_QUICK").is_some() {
        Bencher::quick()
    } else {
        Bencher::default()
    };
    let plat = Platform::core_i9();
    let program = WorkloadId::DeepSeekMoe.build();
    // A realistic mid-search schedule (tiled + annotated).
    let sched = Schedule::new(program.clone());
    let tuned = sched
        .apply(Transform::TileSize { stage: 0, loop_idx: 1, factor: 64 })
        .unwrap()
        .apply(Transform::TileSize { stage: 0, loop_idx: 3, factor: 128 })
        .unwrap()
        .apply(Transform::Parallel { stage: 0, loop_idx: 0 })
        .unwrap();
    let tuned_prog = &tuned.current;

    let mut results = Vec::new();
    results.push(b.run("access::analyze (tiled moe)", || {
        access::analyze(tuned_prog, &tuned_prog.stages[0])
    }));
    results.push(b.run("simulator::simulate (hardware f)", || {
        simulator::simulate(tuned_prog, &plat, 3)
    }));
    results.push(b.run("analytical::predict (surrogate f-hat)", || {
        analytical::predict(tuned_prog, &plat, 3)
    }));
    results.push(b.run("transform apply (TileSize)", || {
        Transform::TileSize { stage: 0, loop_idx: 2, factor: 16 }
            .apply(tuned_prog)
            .unwrap()
    }));
    let mut rng = Pcg::new(5);
    results.push(b.run("sampler::legal_transforms", || {
        sampler::legal_transforms(tuned_prog, &mut rng)
    }));
    let mut rng2 = Pcg::new(6);
    results.push(b.run("sampler::random_sequence(4)", || {
        sampler::random_sequence(tuned_prog, 4, &mut rng2)
    }));
    // Tuning-db hot paths: every Evaluator::measure with a cache attached
    // pays one program fingerprint + one cache lookup before (or instead
    // of) a hardware-model call, so both must stay well above simulator
    // throughput.
    results.push(b.run("db::workload_fingerprint (tiled moe)", || {
        workload_fingerprint(tuned_prog)
    }));
    results.push(b.run("db::program_fingerprint (tiled moe)", || {
        program_fingerprint(tuned_prog)
    }));
    {
        let cache = MeasureCache::new();
        let fp = program_fingerprint(tuned_prog);
        cache.insert(fp, "core_i9", 1.25e-3);
        results.push(b.run("MeasureCache lookup (hit)", || {
            cache.get(fp, "core_i9")
        }));
    }
    results.push(b.run("prompt::render (full Appendix-A prompt)", || {
        let ctx = PromptContext {
            node: &tuned,
            ancestors: vec![&sched],
            scores: vec![0.9, 0.3],
            platform: &plat,
            exemplars: &[],
        };
        reasoning_compiler::reasoning::prompt::render(&ctx)
    }));
    {
        use reasoning_compiler::reasoning::engine::LlmEngine;
        let mut engine = SimulatedLlm::new(ModelProfile::gpt4o_mini(), 7);
        results.push(b.run("SimulatedLlm::complete (proposal round)", || {
            let ctx = PromptContext {
                node: &tuned,
                ancestors: vec![&sched],
                scores: vec![0.9, 0.3],
                platform: &plat,
                exemplars: &[],
            };
            engine.complete(&ctx)
        }));
    }

    // Serial vs batched evaluation: the parallel pipeline, now on the
    // PR-5 persistent executor. One batch is a realistic MCTS/ES
    // measurement slice (64 distinct candidates); the worker counts
    // bracket a typical CI machine. Results are bit-identical across
    // executor widths — only wall-clock moves. The third variant is the
    // pre-PR-5 baseline — scoped threads spawned and joined per batch —
    // so the executor-vs-scoped speedup (no per-batch thread start-up,
    // workers stay hot) is tracked cross-PR in the JSON.
    let (batch_speedup, executor_vs_scoped) = {
        let hw = HardwareModel::new(plat.clone());
        let mut rng3 = Pcg::new(9);
        let cands: Vec<_> = (0..64)
            .map(|_| {
                let seq = sampler::random_sequence(&sched.current, 4, &mut rng3);
                sched.apply_all(&seq).0.current
            })
            .collect();
        let jobs: Vec<LatencyJob> = cands
            .iter()
            .enumerate()
            .map(|(i, p)| LatencyJob { program: p, seed: 100 + i as u64 })
            .collect();
        let serial_exec = Executor::serial();
        let wide_exec = Executor::new(4);
        let serial = b.run("latency_batch x64 (serial executor)", || {
            latency_batch(&hw, &jobs, &serial_exec)
        });
        let batched = b.run("latency_batch x64 (persistent executor, 4 workers)", || {
            latency_batch(&hw, &jobs, &wide_exec)
        });
        // Pre-PR-5 baseline: spawn + join fresh scoped threads per batch
        // (what `util::pool::scoped_chunks` did at every parallel site).
        let scoped = b.run("latency_batch x64 (scoped threads per batch, 4 workers)", || {
            let mut out = vec![0.0f64; jobs.len()];
            let chunk = jobs.len().div_ceil(4);
            let hw = &hw;
            std::thread::scope(|scope| {
                for (js, os) in jobs.chunks(chunk).zip(out.chunks_mut(chunk)) {
                    scope.spawn(move || {
                        for (j, o) in js.iter().zip(os.iter_mut()) {
                            *o = hw.latency(j.program, j.seed);
                        }
                    });
                }
            });
            out
        });
        let speedup = serial.mean_ns / batched.mean_ns.max(1.0);
        let vs_scoped = scoped.mean_ns / batched.mean_ns.max(1.0);
        results.push(serial);
        results.push(batched);
        results.push(scoped);
        (speedup, vs_scoped)
    };

    // Combined inner-loop hot path: one search-tree edge at trace depth >= 8
    // — apply a transform to a deep schedule, fingerprint the result for the
    // tree dedup / measurement-cache probe, then run the paper's 20-repeat
    // measurement protocol against the hardware model. This is the
    // per-candidate cost every search strategy pays. Two variants bracket
    // the PR-3 incremental-evaluation work:
    // - "incremental": CoW apply, memoized per-stage fingerprints, and the
    //   shared AnalysisCache inside `HardwareModel` (the path every search
    //   now runs);
    // - "uncached (pre-PR path)": deep-cloned program (what `apply` cost
    //   before CoW), cleared hash memos (full rehash, the pre-memoization
    //   `program_fingerprint`), and direct `simulator::simulate` (fresh
    //   `access::analyze` per stage per repeat).
    // The printed ratio is the PR-3 acceptance number (target >= 5x).
    let (hotpath_speedup, tracing_overhead_pct) = {
        let attn = WorkloadId::Llama3Attention.build();
        let hw = HardwareModel::new(plat.clone());
        let mut deep = Schedule::new(attn);
        let mut rng4 = Pcg::new(21);
        let mut guard = 0;
        while deep.len() < 8 && guard < 1000 {
            guard += 1;
            if let Some(t) = sampler::random_transform(&deep.current, &mut rng4) {
                if let Ok(next) = deep.apply(t) {
                    deep = next;
                }
            }
        }
        assert!(deep.len() >= 8, "failed to build a depth-8 schedule");
        // One fixed legal transform, applied afresh every iteration.
        let mut step = None;
        for _ in 0..1000 {
            if let Some(t) = sampler::random_transform(&deep.current, &mut rng4) {
                if deep.apply(t.clone()).is_ok() {
                    step = Some(t);
                    break;
                }
            }
        }
        let step = step.expect("no legal transform found on the depth-8 schedule");
        let incremental = b.run("hotpath: apply+fp+simulate x20 (depth 8, incremental)", || {
            let child = deep.apply(step.clone()).unwrap();
            let fp = program_fingerprint(&child.current);
            let mut acc = 0.0;
            for seed in 1..=20u64 {
                acc += hw.latency(&child.current, seed);
            }
            (fp, acc)
        });
        let uncached = b.run("hotpath: apply+fp+simulate x20 (depth 8, uncached pre-PR path)", || {
            let child = deep.apply(step.clone()).unwrap();
            // Reproduce the pre-PR costs: O(program) copy per edge, full
            // program rehash, from-scratch analysis per stage per repeat.
            let frozen = child.current.deep_clone();
            let fp = program_fingerprint(&frozen);
            let mut acc = 0.0;
            for seed in 1..=20u64 {
                acc += simulator::simulate(&frozen, &plat, seed);
            }
            (fp, acc)
        });
        let speedup = uncached.mean_ns / incremental.mean_ns.max(1.0);
        results.push(incremental);
        results.push(uncached);

        // Tracing-overhead variant (PR 6): the same depth-8 edge, each
        // hardware repeat wrapped in a Measure span exactly as the batch
        // evaluator does, timed with the recorder off and then on. The
        // observability acceptance number: the live recorder must cost
        // <3% on the densest span site in the codebase.
        let traced_edge = || {
            let child = deep.apply(step.clone()).unwrap();
            let fp = program_fingerprint(&child.current);
            let mut acc = 0.0;
            for seed in 1..=20u64 {
                let _sp = obs::span(obs::EventKind::Measure, seed);
                acc += hw.latency(&child.current, seed);
            }
            (fp, acc)
        };
        obs::disable();
        let trace_off = b.run("hotpath: depth-8 x20 with spans, recorder off", || traced_edge());
        obs::enable();
        let trace_on = b.run("hotpath: depth-8 x20 with spans, recorder on", || traced_edge());
        obs::disable();
        let _ = obs::drain(); // release the per-thread rings
        let overhead_pct = (trace_on.median_ns / trace_off.median_ns.max(1.0) - 1.0) * 100.0;
        results.push(trace_off);
        results.push(trace_on);
        (speedup, overhead_pct)
    };

    println!("\n== micro hot paths ==");
    for r in &results {
        println!("{}", r.report());
    }
    write_json(&results, tracing_overhead_pct);
    println!(
        "\nbatched evaluation wall-clock speedup (4 workers vs serial, 64-candidate batch): {batch_speedup:.2}x"
    );
    println!(
        "persistent executor vs scoped-threads-per-batch (4 workers, 64-candidate batch): {executor_vs_scoped:.2}x"
    );
    println!(
        "incremental-evaluation speedup on the depth-8 hot path (uncached pre-PR path vs incremental): {hotpath_speedup:.2}x (target >= 5x) — {}",
        if hotpath_speedup >= 5.0 { "PASS" } else { "BELOW TARGET" }
    );
    println!(
        "tracing overhead on the depth-8 hot path (recorder on vs off): {tracing_overhead_pct:.2}% (target < 3%) — {}",
        if tracing_overhead_pct < 3.0 { "PASS" } else { "OVER" }
    );
    // §Perf acceptance: simulator throughput.
    let sim = &results[1];
    println!(
        "\nsimulator eval throughput: {:.0}/s (target >50k/s) — {}",
        sim.throughput_per_s,
        if sim.throughput_per_s > 50_000.0 { "PASS" } else { "BELOW TARGET" }
    );
}
