//! Micro-benchmarks of the search hot paths (EXPERIMENTS.md §Perf).
//!
//! `cargo bench --bench micro_hotpaths`
//!
//! These are the operations executed thousands of times per tuning run:
//! access analysis, simulator/surrogate evaluation, transform application,
//! legal-action enumeration, prompt rendering and a full simulated-LLM
//! proposal round. The §Perf target: simulator eval >50k/s so a full
//! Table-1 sweep stays in minutes.

use reasoning_compiler::cost::{
    access, analytical, latency_batch, simulator, HardwareModel, LatencyJob, Platform,
};
use reasoning_compiler::db::{program_fingerprint, workload_fingerprint, MeasureCache};
use reasoning_compiler::reasoning::{prompt::PromptContext, ModelProfile, SimulatedLlm};
use reasoning_compiler::schedule::{sampler, Schedule, Transform};
use reasoning_compiler::tir::WorkloadId;
use reasoning_compiler::util::bench::Bencher;
use reasoning_compiler::util::rng::Pcg;

fn main() {
    let b = Bencher::default();
    let plat = Platform::core_i9();
    let program = WorkloadId::DeepSeekMoe.build();
    // A realistic mid-search schedule (tiled + annotated).
    let sched = Schedule::new(program.clone());
    let tuned = sched
        .apply(Transform::TileSize { stage: 0, loop_idx: 1, factor: 64 })
        .unwrap()
        .apply(Transform::TileSize { stage: 0, loop_idx: 3, factor: 128 })
        .unwrap()
        .apply(Transform::Parallel { stage: 0, loop_idx: 0 })
        .unwrap();
    let tuned_prog = &tuned.current;

    let mut results = Vec::new();
    results.push(b.run("access::analyze (tiled moe)", || {
        access::analyze(tuned_prog, &tuned_prog.stages[0])
    }));
    results.push(b.run("simulator::simulate (hardware f)", || {
        simulator::simulate(tuned_prog, &plat, 3)
    }));
    results.push(b.run("analytical::predict (surrogate f-hat)", || {
        analytical::predict(tuned_prog, &plat, 3)
    }));
    results.push(b.run("transform apply (TileSize)", || {
        Transform::TileSize { stage: 0, loop_idx: 2, factor: 16 }
            .apply(tuned_prog)
            .unwrap()
    }));
    let mut rng = Pcg::new(5);
    results.push(b.run("sampler::legal_transforms", || {
        sampler::legal_transforms(tuned_prog, &mut rng)
    }));
    let mut rng2 = Pcg::new(6);
    results.push(b.run("sampler::random_sequence(4)", || {
        sampler::random_sequence(tuned_prog, 4, &mut rng2)
    }));
    // Tuning-db hot paths: every Evaluator::measure with a cache attached
    // pays one program fingerprint + one cache lookup before (or instead
    // of) a hardware-model call, so both must stay well above simulator
    // throughput.
    results.push(b.run("db::workload_fingerprint (tiled moe)", || {
        workload_fingerprint(tuned_prog)
    }));
    results.push(b.run("db::program_fingerprint (tiled moe)", || {
        program_fingerprint(tuned_prog)
    }));
    {
        let cache = MeasureCache::new();
        let fp = program_fingerprint(tuned_prog);
        cache.insert(fp, "core_i9", 1.25e-3);
        results.push(b.run("MeasureCache lookup (hit)", || {
            cache.get(fp, "core_i9")
        }));
    }
    results.push(b.run("prompt::render (full Appendix-A prompt)", || {
        let ctx = PromptContext {
            node: &tuned,
            ancestors: vec![&sched],
            scores: vec![0.9, 0.3],
            platform: &plat,
        };
        reasoning_compiler::reasoning::prompt::render(&ctx)
    }));
    {
        use reasoning_compiler::reasoning::engine::LlmEngine;
        let mut engine = SimulatedLlm::new(ModelProfile::gpt4o_mini(), 7);
        results.push(b.run("SimulatedLlm::complete (proposal round)", || {
            let ctx = PromptContext {
                node: &tuned,
                ancestors: vec![&sched],
                scores: vec![0.9, 0.3],
                platform: &plat,
            };
            engine.complete(&ctx)
        }));
    }

    // Serial vs batched evaluation: the PR-2 parallel pipeline. One batch
    // is a realistic MCTS/ES measurement slice (64 distinct candidates);
    // the worker counts bracket a typical CI machine. Results are
    // bit-identical across worker counts — only wall-clock moves.
    let batch_speedup = {
        let hw = HardwareModel { platform: plat.clone() };
        let mut rng3 = Pcg::new(9);
        let cands: Vec<_> = (0..64)
            .map(|_| {
                let seq = sampler::random_sequence(&sched.current, 4, &mut rng3);
                sched.apply_all(&seq).0.current
            })
            .collect();
        let jobs: Vec<LatencyJob> = cands
            .iter()
            .enumerate()
            .map(|(i, p)| LatencyJob { program: p, seed: 100 + i as u64 })
            .collect();
        let serial = b.run("latency_batch x64 (workers=1, serial)", || {
            latency_batch(&hw, &jobs, 1)
        });
        let batched = b.run("latency_batch x64 (workers=4, pooled)", || {
            latency_batch(&hw, &jobs, 4)
        });
        let speedup = serial.mean_ns / batched.mean_ns.max(1.0);
        results.push(serial);
        results.push(batched);
        speedup
    };

    println!("\n== micro hot paths ==");
    for r in &results {
        println!("{}", r.report());
    }
    println!(
        "\nbatched evaluation wall-clock speedup (4 workers vs serial, 64-candidate batch): {batch_speedup:.2}x"
    );
    // §Perf acceptance: simulator throughput.
    let sim = &results[1];
    println!(
        "\nsimulator eval throughput: {:.0}/s (target >50k/s) — {}",
        sim.throughput_per_s,
        if sim.throughput_per_s > 50_000.0 { "PASS" } else { "BELOW TARGET" }
    );
}
