//! Bench: regenerate Figure 3 / Table 3 (convergence curves, 3 methods x
//! 5 kernels on the Intel Core i9 environment).
//!
//! `cargo bench --bench figure3_convergence` — set RC_SCALE=smoke|default|full.

use reasoning_compiler::report::{figure3, Scale};
use std::time::Instant;

fn scale() -> Scale {
    std::env::var("RC_SCALE")
        .ok()
        .and_then(|s| Scale::from_name(&s))
        .unwrap_or(Scale::Default)
}

fn main() {
    let t0 = Instant::now();
    let r = figure3::run(scale(), 42);
    println!("{}", r.markdown);
    eprintln!("[bench] figure3 regenerated in {:.1}s", t0.elapsed().as_secs_f64());
}
