//! Coordinator integration: config files, sessions across platforms,
//! end-to-end tuning, report regenerators and (when artifacts exist) the
//! serving stack.

use std::io::Write;

use reasoning_compiler::coordinator::{
    run_e2e, run_session, Server, ServerConfig, Strategy, TuneConfig,
};
use reasoning_compiler::cost::Platform;
use reasoning_compiler::report::{costs, figure3, platforms, Scale};
use reasoning_compiler::runtime::Manifest;
use reasoning_compiler::tir::workload;

#[test]
fn config_file_roundtrip() {
    let dir = std::env::temp_dir().join(format!("rcc_cfg_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("tune.toml");
    let mut f = std::fs::File::create(&path).unwrap();
    writeln!(
        f,
        "workload = \"flux_attention\"\nplatform = \"xeon_e3\"\n\
         [search]\nstrategy = \"rc\"\nbudget = 44\nrepeats = 3\n\
         [llm]\nmodel = \"o1_mini\"\nhistory_depth = 3\n"
    )
    .unwrap();
    let cfg = TuneConfig::from_file(&path).unwrap();
    assert_eq!(cfg.workload, "flux_attention");
    assert_eq!(cfg.platform, "xeon_e3");
    assert_eq!(cfg.strategy, Strategy::LlmMcts);
    assert_eq!(cfg.budget, 44);
    assert_eq!(cfg.model, "o1_mini");
    assert_eq!(cfg.history_depth, 3);
    // And the config actually drives a session.
    let s = run_session(&cfg).expect("session");
    assert_eq!(s.runs.len(), 3);
    assert!(s.mean_speedup() > 1.0);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn repo_configs_parse_and_run() {
    // Every shipped config must stay valid.
    for entry in std::fs::read_dir("configs").expect("configs/ directory") {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("toml") {
            continue;
        }
        let mut cfg = TuneConfig::from_file(&path)
            .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
        cfg.budget = cfg.budget.min(20);
        cfg.repeats = 1;
        // Keep the test hermetic: configs that enable the tuning database
        // (e.g. warm_start.toml) must not read or grow the developer's
        // real results/tuning_db.jsonl.
        let tmp_db = cfg.db_path.is_some().then(|| {
            std::env::temp_dir().join(format!(
                "rcc_cfg_db_{}_{}.jsonl",
                std::process::id(),
                path.file_stem().unwrap().to_string_lossy()
            ))
        });
        if let Some(p) = &tmp_db {
            std::fs::remove_file(p).ok();
            cfg.db_path = Some(p.to_string_lossy().to_string());
        }
        let s = run_session(&cfg).expect("session");
        assert!(!s.runs.is_empty(), "{}", path.display());
        if let Some(p) = &tmp_db {
            std::fs::remove_file(p).ok();
        }
    }
}

#[test]
fn sessions_work_on_every_platform() {
    for plat in Platform::all() {
        let cfg = TuneConfig {
            strategy: Strategy::LlmMcts,
            platform: plat.name.to_string(),
            budget: 25,
            repeats: 2,
            ..Default::default()
        };
        let s = run_session(&cfg).expect("session");
        assert!(
            s.mean_speedup() > 1.0,
            "{}: speedup {}",
            plat.name,
            s.mean_speedup()
        );
    }
}

#[test]
fn e2e_driver_beats_baseline_and_counts_samples() {
    let tasks = workload::llama3_e2e_test();
    let cfg = TuneConfig {
        strategy: Strategy::LlmMcts,
        budget: 45,
        repeats: 2,
        ..Default::default()
    };
    let r = run_e2e(&tasks, &cfg).expect("e2e");
    assert_eq!(r.tasks.len(), tasks.len());
    assert!(r.weighted_speedup > 1.0);
    assert!(r.total_samples > 0 && r.total_samples <= 45);
}

#[test]
fn report_regenerators_emit_wellformed_json() {
    let f = figure3::run(Scale::Smoke, 9);
    let parsed = reasoning_compiler::util::Json::parse(&f.json.to_string()).unwrap();
    assert!(parsed.get("series").is_some());

    let t8 = costs::table8(Scale::Smoke, 9);
    assert_eq!(t8.json.get("rows").unwrap().as_arr().unwrap().len(), 6);
}

#[test]
fn table1_headline_shape_holds_at_smoke_scale() {
    // The paper's headline: RC achieves higher speedup with fewer samples.
    let r = platforms::table1(Scale::Smoke, 4);
    let rc = r.json.get("geomean_rc_speedup").unwrap().as_f64().unwrap();
    let es = r.json.get("geomean_es_speedup").unwrap().as_f64().unwrap();
    let red = r
        .json
        .get("geomean_sample_reduction")
        .unwrap()
        .as_f64()
        .unwrap();
    assert!(rc > es, "RC geomean {rc:.2} should beat ES {es:.2}");
    assert!(red > 1.0, "sample reduction {red:.2} should exceed 1");
}

#[test]
fn serving_stack_over_artifacts() {
    let Ok(manifest) = Manifest::discover() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    if !cfg!(feature = "xla") {
        eprintln!("skipping: built without the xla feature");
        return;
    }
    let mut server = Server::start(
        &manifest,
        ServerConfig { max_batch: 4, target_delay_ticks: 4096, ..Default::default() },
    )
    .unwrap();
    // Mixed workload across all models.
    for (i, name) in manifest.artifacts.keys().cycle().take(20).enumerate() {
        server.submit(name, i as u64).unwrap();
    }
    let served = server.drain().unwrap();
    assert_eq!(served, 20);
    assert_eq!(server.metrics.total_requests(), 20);
    let report = server.metrics.report();
    assert!(report.contains("llama3_block"));
}
