//! Integration tests of the parallel batched evaluation pipeline (PR 2,
//! re-based onto the persistent executor in PR 5): the determinism
//! contract (executor width never changes results; leaf-parallel MCTS is
//! bit-reproducible per seed), concurrent measurement-cache accounting,
//! and concurrent sessions sharing one file-locked database.

use std::path::PathBuf;

use reasoning_compiler::coordinator::{run_session, Strategy, TuneConfig};
use reasoning_compiler::cost::{HardwareModel, Platform, SurrogateModel};
use reasoning_compiler::db::{program_fingerprint, Database, MeasureCache};
use reasoning_compiler::schedule::{Schedule, Transform};
use reasoning_compiler::search::{
    evolutionary_search, mcts_search, EvoConfig, Evaluator, EvolutionaryStrategy, MctsConfig,
    MctsStrategy, RandomPolicy, SearchContext, SearchResult, SearchStrategy,
};
use reasoning_compiler::tir::workload::WorkloadId;
use reasoning_compiler::tir::Program;
use reasoning_compiler::util::executor::Executor;

fn curve_key(r: &SearchResult) -> Vec<(usize, u64)> {
    r.curve.iter().map(|m| (m.sample, m.latency.to_bits())).collect()
}

struct Models {
    base: Program,
    platform: Platform,
    surrogate: SurrogateModel,
    hardware: HardwareModel,
}

fn models(workload: WorkloadId) -> Models {
    let platform = Platform::core_i9();
    Models {
        base: workload.build(),
        surrogate: SurrogateModel::new(platform.clone()),
        hardware: HardwareModel::new(platform.clone()),
        platform,
    }
}

fn mcts_ctx_run(m: &Models, budget: usize, seed: u64, workers: usize, eval_batch: usize) -> SearchResult {
    let mut ctx =
        SearchContext::new(&m.base, &m.surrogate, &m.hardware, &m.platform, budget, seed);
    ctx.executor = Executor::new(workers);
    ctx.eval_batch = eval_batch;
    let mut policy = RandomPolicy::new(seed);
    MctsStrategy::new(MctsConfig::default(), &mut policy).search(&ctx)
}

fn evo_ctx_run(m: &Models, budget: usize, seed: u64, workers: usize) -> SearchResult {
    let mut ctx =
        SearchContext::new(&m.base, &m.surrogate, &m.hardware, &m.platform, budget, seed);
    ctx.executor = Executor::new(workers);
    EvolutionaryStrategy::new(EvoConfig::default()).search(&ctx)
}

#[test]
fn strategy_trait_with_workers_one_matches_legacy_serial_functions() {
    let m = models(WorkloadId::DeepSeekMoe);
    // MCTS through the trait (serial context) == the legacy free function.
    let via_trait = mcts_ctx_run(&m, 40, 7, 1, 1);
    let mut policy = RandomPolicy::new(7);
    let legacy = mcts_search(
        &m.base,
        &mut policy,
        &m.surrogate,
        &m.hardware,
        &MctsConfig::default(),
        &m.platform,
        40,
        7,
    );
    assert_eq!(via_trait.best_latency, legacy.best_latency);
    assert_eq!(curve_key(&via_trait), curve_key(&legacy));

    // Evolutionary likewise.
    let via_trait = evo_ctx_run(&m, 60, 7, 1);
    let legacy = evolutionary_search(
        &m.base,
        &m.surrogate,
        &m.hardware,
        &EvoConfig::default(),
        &m.platform,
        60,
        7,
    );
    assert_eq!(via_trait.best_latency, legacy.best_latency);
    assert_eq!(curve_key(&via_trait), curve_key(&legacy));
}

#[test]
fn evolutionary_workers_do_not_change_results() {
    // The per-generation measurement slice is fixed before any hardware
    // runs, so the worker pool is pure wall-clock: bit-identical curves.
    let m = models(WorkloadId::Llama4Mlp);
    for seed in [1, 9] {
        let serial = evo_ctx_run(&m, 80, seed, 1);
        for workers in [2, 4] {
            let parallel = evo_ctx_run(&m, 80, seed, workers);
            assert_eq!(curve_key(&serial), curve_key(&parallel), "workers={workers}");
            assert_eq!(serial.best_latency, parallel.best_latency);
            assert_eq!(serial.best_trace, parallel.best_trace);
        }
    }
}

#[test]
fn mcts_batch_one_matches_serial_for_any_worker_count() {
    let m = models(WorkloadId::DeepSeekMoe);
    let serial = mcts_ctx_run(&m, 40, 3, 1, 1);
    for workers in [2, 4] {
        let parallel = mcts_ctx_run(&m, 40, 3, workers, 1);
        assert_eq!(curve_key(&serial), curve_key(&parallel), "workers={workers}");
    }
}

#[test]
fn leaf_parallel_mcts_is_deterministic_per_seed_and_still_improves() {
    let m = models(WorkloadId::DeepSeekMoe);
    let a = mcts_ctx_run(&m, 60, 5, 4, 4);
    let b = mcts_ctx_run(&m, 60, 5, 4, 4);
    assert_eq!(curve_key(&a), curve_key(&b), "same seed => identical run");
    assert_eq!(a.best_latency, b.best_latency);
    // Worker count alone must not perturb the leaf-parallel trajectory.
    let c = mcts_ctx_run(&m, 60, 5, 2, 4);
    assert_eq!(curve_key(&a), curve_key(&c), "trajectory depends on batch, not workers");
    // A different seed takes a different path. (Compare whole curves, not
    // best latencies: distinct seeds may legitimately converge to the same
    // optimum.)
    let d = mcts_ctx_run(&m, 60, 6, 4, 4);
    assert_ne!(curve_key(&a), curve_key(&d));
    // Leaf parallelism must remain an effective search.
    assert!(a.best_speedup() > 1.3, "leaf-parallel speedup {}", a.best_speedup());
    assert!(a.samples_used <= 60);
}

#[test]
fn session_worker_pool_does_not_change_session_results() {
    let base = TuneConfig {
        strategy: Strategy::Mcts,
        budget: 30,
        repeats: 3,
        ..Default::default()
    };
    let serial = run_session(&TuneConfig { workers: 1, ..base.clone() }).unwrap();
    let pooled = run_session(&TuneConfig { workers: 4, ..base.clone() }).unwrap();
    assert_eq!(
        serial.runs.iter().map(|r| r.best_latency).collect::<Vec<_>>(),
        pooled.runs.iter().map(|r| r.best_latency).collect::<Vec<_>>()
    );
}

#[test]
fn concurrent_cache_hits_are_counted_correctly() {
    // One shared cache, several threads evaluating the same known
    // schedule: every evaluation is a hit, no thread consumes budget, and
    // each evaluator's private counters add up exactly.
    let base = WorkloadId::Llama4Mlp.build_test();
    let hw = HardwareModel::new(Platform::core_i9());
    let sched = Schedule::new(base.clone())
        .apply(Transform::Parallel { stage: 0, loop_idx: 0 })
        .unwrap();
    let fp = program_fingerprint(&sched.current);
    let cache = MeasureCache::new();
    cache.insert(fp, "core_i9", 0.125);

    const THREADS: usize = 6;
    const LOOKUPS: usize = 50;
    let hits: usize = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for _ in 0..THREADS {
            let shared = cache.share();
            let hw = &hw;
            let base = &base;
            let sched = &sched;
            handles.push(scope.spawn(move || {
                let mut ev = Evaluator::with_cache(hw, base, 5, 7, shared, "core_i9");
                for _ in 0..LOOKUPS {
                    assert_eq!(ev.measure(sched), Some(0.125));
                }
                assert_eq!(ev.used, 0, "hits must not consume budget");
                ev.cache_counts().0
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).sum()
    });
    assert_eq!(hits, THREADS * LOOKUPS);
}

fn temp_db(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!(
        "rcc_par_{tag}_{}_{}.jsonl",
        std::process::id(),
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos()
    ))
}

#[test]
fn concurrent_sessions_share_one_database_without_losing_records() {
    // Two independent tuner "processes" (separate Database handles under
    // the advisory file lock) commit to one path; nothing is lost or torn.
    let db_path = temp_db("sessions");
    let mk = |workload: &str, seed: u64| TuneConfig {
        strategy: Strategy::Mcts,
        workload: workload.to_string(),
        budget: 25,
        repeats: 2,
        seed,
        db_path: Some(db_path.to_string_lossy().to_string()),
        workers: 1,
        ..Default::default()
    };
    std::thread::scope(|scope| {
        let a = scope.spawn(|| run_session(&mk("deepseek_moe", 42)).unwrap());
        let b = scope.spawn(|| run_session(&mk("llama4_mlp", 77)).unwrap());
        a.join().unwrap();
        b.join().unwrap();
    });
    let db = Database::open(&db_path).unwrap();
    assert_eq!(db.skipped_lines, 0, "no torn lines under concurrent commits");
    let stats = db.stats();
    assert_eq!(stats.workloads.len(), 2, "both sessions' records survive");
    std::fs::remove_file(&db_path).ok();
}

#[test]
fn reasoning_engines_and_strategies_are_send() {
    // The worker pools move/borrow these across threads; keep the bounds
    // compiler-verified (ISSUE 2: "engines must be Send — verify impls").
    fn assert_send<T: Send>() {}
    assert_send::<reasoning_compiler::reasoning::SimulatedLlm>();
    assert_send::<reasoning_compiler::reasoning::LlmPolicy<reasoning_compiler::reasoning::SimulatedLlm>>();
    assert_send::<EvolutionaryStrategy>();
    assert_send::<MeasureCache>();
    fn assert_sync<T: Sync>() {}
    assert_sync::<MeasureCache>();
}
