//! Integration tests of the transfer-tuning subsystem (PR 4).
//!
//! The acceptance property is the paper's sample-efficiency claim applied
//! across workloads: after tuning workload A, a search on a structurally
//! similar workload B with `--transfer` must reach B's cold-search best
//! latency in at most half the hardware samples the cold search needed.
//! Alongside it: the rebase legality property (a rebased trace never
//! carries an out-of-range split/tile or dangling stage reference — it
//! always replays fully), end-to-end exemplar flow, and the `--no-transfer`
//! escape hatch reproducing the cold run bit-for-bit.

use std::path::PathBuf;

use reasoning_compiler::coordinator::{run_session_on, Strategy, TuneConfig};
use reasoning_compiler::db::Database;
use reasoning_compiler::schedule::{sampler, Schedule};
use reasoning_compiler::tir::workload;
use reasoning_compiler::transfer::{derive_hints, rebase_trace};
use reasoning_compiler::util::Pcg;

fn temp_db(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!(
        "rcc_transfer_{tag}_{}_{}.jsonl",
        std::process::id(),
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos()
    ))
}

/// Workload A: the "prior work" the database accumulates.
fn workload_a() -> reasoning_compiler::tir::Program {
    workload::moe_matmul("transfer_src", 32, 512, 256)
}

/// Workload B: structurally similar (same shape class), different extents.
fn workload_b() -> reasoning_compiler::tir::Program {
    workload::moe_matmul("transfer_dst", 16, 256, 128)
}

#[test]
fn transfer_halves_samples_to_cold_best() {
    let db_path = temp_db("accept");
    let db_str = db_path.to_string_lossy().to_string();

    // ---- accumulate prior work: tune A with the strong (LLM) strategy ----
    let cfg_a = TuneConfig {
        strategy: Strategy::LlmMcts,
        budget: 120,
        repeats: 2,
        seed: 42,
        db_path: Some(db_str.clone()),
        workers: 1,
        ..Default::default()
    };
    let a = run_session_on(&workload_a(), &cfg_a).expect("tune A");
    assert!(a.mean_speedup() > 1.0, "A must improve to seed the db");
    let db = Database::open(&db_path).expect("reopen db");
    assert!(!db.is_empty(), "A's session must commit records");
    assert!(
        db.records().iter().all(|r| r.shape_class != 0 && !r.extents.is_empty()),
        "new records must carry transfer metadata"
    );

    // ---- cold search on B: no database at all ---------------------------
    let cfg_cold = TuneConfig {
        strategy: Strategy::Mcts,
        budget: 100,
        repeats: 1,
        seed: 7,
        db_path: None,
        workers: 1,
        ..Default::default()
    };
    let cold = run_session_on(&workload_b(), &cfg_cold).expect("cold B");
    let cold_run = &cold.runs[0];
    let target = cold_run.best_speedup();
    assert!(target > 1.0, "cold search must improve");
    let cold_samples = cold_run
        .samples_to_reach(target)
        .expect("cold run reached its own best");
    assert!(
        cold_samples >= 2,
        "degenerate cold run (best at sample {cold_samples}) cannot halve"
    );

    // ---- transfer-warm search on B: A's records, rebased ----------------
    // B's own fingerprint has no records, so everything the warm start
    // knows came through the cross-workload transfer path.
    let cfg_warm = TuneConfig {
        db_path: Some(db_str.clone()),
        ..cfg_cold.clone()
    };
    let warm = run_session_on(&workload_b(), &cfg_warm).expect("transfer B");
    let warm_run = &warm.runs[0];
    // Same seed => identical baseline, so speedup targets are comparable.
    assert_eq!(
        warm_run.baseline_latency, cold_run.baseline_latency,
        "same seed must measure the same baseline"
    );
    assert!(
        warm_run.best_speedup() >= target,
        "transfer-warm search must match the cold best ({:.3}x vs {target:.3}x)",
        warm_run.best_speedup()
    );
    let warm_samples = warm_run
        .samples_to_reach(target)
        .expect("transfer-warm run must reach the cold best");
    assert!(
        warm_samples.saturating_mul(2) <= cold_samples,
        "transfer must reach the cold best ({target:.3}x) in <= 50% of the cold \
         samples: warm {warm_samples} vs cold {cold_samples}"
    );

    std::fs::remove_file(&db_path).ok();
}

#[test]
fn no_transfer_reproduces_the_cold_run_exactly() {
    let db_path = temp_db("escape");
    let db_str = db_path.to_string_lossy().to_string();
    let cfg_a = TuneConfig {
        strategy: Strategy::LlmMcts,
        budget: 60,
        repeats: 1,
        seed: 42,
        db_path: Some(db_str.clone()),
        workers: 1,
        ..Default::default()
    };
    run_session_on(&workload_a(), &cfg_a).expect("tune A");

    let cfg_cold = TuneConfig {
        strategy: Strategy::Mcts,
        budget: 40,
        repeats: 1,
        seed: 9,
        db_path: None,
        workers: 1,
        ..Default::default()
    };
    let cold = run_session_on(&workload_b(), &cfg_cold).expect("cold B");

    // Database attached but transfer disabled: B has no records of its own,
    // so the session must be bit-identical to the cold run.
    let cfg_off = TuneConfig {
        db_path: Some(db_str.clone()),
        transfer: false,
        ..cfg_cold.clone()
    };
    let off = run_session_on(&workload_b(), &cfg_off).expect("no-transfer B");
    assert_eq!(off.runs[0].best_latency, cold.runs[0].best_latency);
    assert_eq!(off.runs[0].samples_used, cold.runs[0].samples_used);
    assert_eq!(off.runs[0].curve.len(), cold.runs[0].curve.len());
    assert_eq!(off.runs[0].cache_hits, 0, "nothing to hit without transfer");

    std::fs::remove_file(&db_path).ok();
}

#[test]
fn derive_hints_feeds_legal_warm_entries_and_exemplars() {
    let db_path = temp_db("hints");
    let db_str = db_path.to_string_lossy().to_string();
    let cfg_a = TuneConfig {
        strategy: Strategy::LlmMcts,
        budget: 80,
        repeats: 2,
        seed: 1,
        db_path: Some(db_str),
        workers: 1,
        ..Default::default()
    };
    run_session_on(&workload_a(), &cfg_a).expect("tune A");
    let db = Database::open(&db_path).unwrap();

    let b = workload_b();
    let hints = derive_hints(&db, &b, "core_i9", 4);
    assert!(!hints.warm_entries.is_empty(), "similar records must surface");
    assert!(!hints.exemplars.is_empty());
    let base = Schedule::new(b.clone());
    for (trace, _) in &hints.warm_entries {
        let (replayed, applied) = base.apply_all(trace);
        assert_eq!(applied, trace.len(), "warm entries must replay fully");
        replayed.current.validate().unwrap();
    }
    for ex in &hints.exemplars {
        let (_, applied) = base.apply_all(&ex.trace);
        assert_eq!(applied, ex.trace.len(), "exemplar traces must replay fully");
        assert!(!ex.rendered.is_empty());
    }

    // An LLM session on B consumes the exemplars end-to-end.
    let cfg_b = TuneConfig {
        strategy: Strategy::LlmMcts,
        budget: 40,
        repeats: 1,
        seed: 3,
        db_path: Some(db_path.to_string_lossy().to_string()),
        workers: 1,
        ..Default::default()
    };
    let session = run_session_on(&b, &cfg_b).expect("LLM session with exemplars");
    assert!(session.mean_speedup() > 1.0);
    assert!(session.llm_costs.calls > 0);

    std::fs::remove_file(&db_path).ok();
}

/// Rebase legality property: for random source traces and random
/// target shapes — same shape class or not — the rebased trace always
/// replays fully on the target and the result validates. This is the
/// "never an out-of-range split or dangling stage reference" guarantee.
#[test]
fn rebase_never_produces_illegal_traces() {
    let mut rng = Pcg::new(0xBA5E);
    let token_choices = [2i64, 4, 8, 16, 32];
    let dim_choices = [48i64, 64, 96, 128, 256, 384, 512];
    let pick = |xs: &[i64], rng: &mut Pcg| xs[rng.gen_range(xs.len())];

    for case in 0..60 {
        // Random source program + random trace discovered on it.
        let (src, dst) = match case % 3 {
            0 => (
                workload::moe_matmul(
                    "s",
                    pick(&token_choices, &mut rng),
                    pick(&dim_choices, &mut rng),
                    pick(&dim_choices, &mut rng),
                ),
                workload::moe_matmul(
                    "d",
                    pick(&token_choices, &mut rng),
                    pick(&dim_choices, &mut rng),
                    pick(&dim_choices, &mut rng),
                ),
            ),
            1 => (
                workload::attention("s", 2 + rng.gen_range(6) as i64, 64, 32),
                workload::attention("d", 2 + rng.gen_range(6) as i64, 128, 64),
            ),
            // Cross-kernel rebase: structurally unrelated programs must
            // degrade to dropped steps, never to illegal output.
            _ => (
                workload::attention("s", 4, 64, 32),
                workload::moe_matmul(
                    "d",
                    pick(&token_choices, &mut rng),
                    pick(&dim_choices, &mut rng),
                    pick(&dim_choices, &mut rng),
                ),
            ),
        };
        let len = 2 + rng.gen_range(7);
        let trace = sampler::random_sequence(&src, len, &mut rng);
        let outcome = rebase_trace(&dst, &trace);
        assert_eq!(
            outcome.trace.len() + outcome.dropped,
            trace.len(),
            "every input step is either kept or dropped"
        );

        let sched = Schedule::new(dst.clone());
        let (replayed, applied) = sched.apply_all(&outcome.trace);
        assert_eq!(
            applied,
            outcome.trace.len(),
            "case {case}: rebased trace must replay fully ({:?})",
            outcome.trace
        );
        replayed.current.validate().unwrap_or_else(|e| {
            panic!("case {case}: rebased program invalid: {e}");
        });
        // Every surviving step stays in range by construction; spot-check
        // the stage references anyway.
        for t in &outcome.trace {
            assert!(t.stage() < dst.stages.len(), "dangling stage reference");
        }
    }
}
