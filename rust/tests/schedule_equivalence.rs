//! Property tests: every legal transformation sequence is semantics-
//! preserving — the core compiler-correctness contract (§2: transformed
//! programs are "semantically equivalent to the original program").
//!
//! Uses the in-repo property harness (`util::prop`): random schedules are
//! generated per workload and validated against three oracles:
//! 1. structural invariants (`Program::validate`),
//! 2. exact iteration-space coverage (each axis tuple visited exactly once),
//! 3. interpreter output equality vs the unscheduled program (tolerance
//!    absorbs float reassociation).

use reasoning_compiler::schedule::{sampler, Schedule};
use reasoning_compiler::tir::interp;
use reasoning_compiler::tir::workload::WorkloadId;
use reasoning_compiler::util::prop;
use reasoning_compiler::util::rng::Pcg;

/// Generate a random schedule of up to `max_len` transforms on a workload.
fn random_schedule(w: WorkloadId, max_len: usize, rng: &mut Pcg) -> Schedule {
    let base = Schedule::new(w.build_test());
    let len = 1 + rng.gen_range(max_len);
    let seq = sampler::random_sequence(&base.current, len, rng);
    let (sched, _) = base.apply_all(&seq);
    sched
}

#[test]
fn random_schedules_preserve_structure_and_space() {
    for w in WorkloadId::ALL {
        prop::check(
            &format!("structure+space[{}]", w.name()),
            0xA11CE ^ w.name().len() as u64,
            40,
            |rng| random_schedule(w, 8, rng).trace.to_vec(),
            |trace| {
                let base = Schedule::new(w.build_test());
                let (sched, applied) = base.apply_all(trace);
                if applied != trace.len() {
                    return Err(format!("replay applied {applied}/{}", trace.len()));
                }
                sched.current.validate().map_err(|e| e.to_string())?;
                for stage in &sched.current.stages {
                    interp::iteration_space(stage).map_err(|e| e.to_string())?;
                }
                Ok(())
            },
        );
    }
}

#[test]
fn random_schedules_preserve_semantics() {
    for w in WorkloadId::ALL {
        let reference = interp::run_seeded(&w.build_test(), 1234);
        prop::check(
            &format!("semantics[{}]", w.name()),
            0xBEEF ^ w.name().len() as u64,
            25,
            |rng| random_schedule(w, 6, rng),
            |sched| {
                let mut tensors = interp::Tensors::seeded(&sched.current, 1234);
                interp::execute(&sched.current, &mut tensors);
                let got = tensors.output(&sched.current);
                if interp::outputs_close(&reference, got, 2e-3) {
                    Ok(())
                } else {
                    Err(format!(
                        "output mismatch after {:?}",
                        sched.trace.iter().map(|t| t.op_name()).collect::<Vec<_>>()
                    ))
                }
            },
        );
    }
}

#[test]
fn trace_replay_is_deterministic() {
    prop::check(
        "replay-determinism",
        0x5EED,
        60,
        |rng| {
            let w = *rng.choose(&WorkloadId::ALL);
            (w, random_schedule(w, 8, rng).trace.to_vec())
        },
        |(w, trace)| {
            let a = Schedule::new(w.build_test()).apply_all(trace).0;
            let b = Schedule::new(w.build_test()).apply_all(trace).0;
            if a.fingerprint() == b.fingerprint() {
                Ok(())
            } else {
                Err("replay fingerprints differ".into())
            }
        },
    );
}

#[test]
fn fingerprints_distinguish_different_loop_structures() {
    // Across many random schedules of one workload, schedules with
    // different loop signatures must not collide (fingerprint is the MCTS
    // dedup key).
    use std::collections::HashMap;
    let mut rng = Pcg::new(77);
    let mut by_fp: HashMap<u64, String> = HashMap::new();
    for _ in 0..300 {
        let sched = random_schedule(WorkloadId::DeepSeekMoe, 6, &mut rng);
        let sig: String = sched
            .current
            .stages
            .iter()
            .map(|s| reasoning_compiler::tir::printer::loop_signature(s))
            .collect::<Vec<_>>()
            .join("|")
            + &format!(
                "|cw={}|ca={:?}",
                sched.current.stages[0].cache_write, sched.current.stages[0].compute_at
            );
        let fp = sched.fingerprint();
        if let Some(prev) = by_fp.get(&fp) {
            assert_eq!(prev, &sig, "fingerprint collision between distinct structures");
        } else {
            by_fp.insert(fp, sig);
        }
    }
}

#[test]
fn interpreter_matches_across_seeds() {
    // Different input seeds must produce different outputs (inputs actually
    // flow through), while the same seed reproduces exactly.
    for w in WorkloadId::ALL {
        let p = w.build_test();
        let a = interp::run_seeded(&p, 5);
        let b = interp::run_seeded(&p, 5);
        let c = interp::run_seeded(&p, 6);
        assert_eq!(a, b, "{}", w.name());
        assert_ne!(a, c, "{}", w.name());
    }
}

#[test]
fn deep_transform_chains_stay_legal() {
    // Long chains (up to 20 transforms) must keep validating — exercises
    // index bookkeeping through repeated splits/fuses/reorders.
    prop::check(
        "deep-chains",
        0xDEEF,
        20,
        |rng| random_schedule(WorkloadId::Llama4Mlp, 20, rng),
        |sched| {
            sched.current.validate().map_err(|e| e.to_string())?;
            let replayed = sched.replay().map_err(|e| e.to_string())?;
            replayed.validate().map_err(|e| e.to_string())
        },
    );
}

#[test]
fn informed_proposals_preserve_semantics_too() {
    // The reasoning engine's sequences are *planned*, not sampled — verify
    // they obey the same contract on the miniature workloads.
    use reasoning_compiler::cost::{AnalysisCache, Platform};
    use reasoning_compiler::reasoning::engine::informed_proposals;
    let analysis = AnalysisCache::new();
    for w in WorkloadId::ALL {
        for plat in Platform::all() {
            let base = Schedule::new(w.build_test());
            let reference = interp::run_seeded(&base.current, 99);
            let mut rng = Pcg::new(3);
            let (seq, _) =
                informed_proposals(&base, &plat, &Default::default(), &analysis, &mut rng);
            let (sched, _) = base.apply_all(&seq);
            let got = interp::run_seeded(&sched.current, 99);
            assert!(
                interp::outputs_close(&reference, &got, 2e-3),
                "{} on {}: informed proposal broke semantics",
                w.name(),
                plat.name
            );
        }
    }
}
