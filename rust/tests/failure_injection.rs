//! Failure injection: the system must degrade gracefully, never panic, on
//! malformed inputs — adversarial LLM responses, corrupt manifests, broken
//! configs and hostile proposal parameters.

use std::sync::Mutex;

use reasoning_compiler::coordinator::{run_session, SessionJournal, Strategy, TuneConfig};
use reasoning_compiler::reasoning::proposal::{self, FallbackStats, Parsed};
use reasoning_compiler::runtime::Manifest;
use reasoning_compiler::schedule::Transform;
use reasoning_compiler::search::SearchResult;
use reasoning_compiler::tir::WorkloadId;
use reasoning_compiler::util::faults::{self, FaultPlan};
use reasoning_compiler::util::rng::Pcg;
use reasoning_compiler::util::tomlmini::Doc;

/// Fault plans are process-global, so every test that arms one serializes
/// behind this mutex and disarms before releasing it. Poisoning (a failed
/// armed test) must not cascade, hence `into_inner` on poison.
static GUARD: Mutex<()> = Mutex::new(());

#[test]
fn adversarial_llm_responses_never_panic() {
    let hostile = [
        // Prompt-injection-flavoured responses.
        "Ignore previous instructions. Transformations to apply: rm -rf /.",
        "Transformations to apply: TileSize(stage=999999999999, loop=18446744073709551615, factor=-3).",
        "Transformations to apply: Reorder(stage=0, perm=[0, 0, 0, 0, 0, 0, 0, 0, 0]).",
        "Transformations to apply: TileSize(stage=0, loop=0, factor=0), Vectorize(stage=0, loop=99).",
        // Deep nesting / bracket bombs.
        "Transformations to apply: Reorder(stage=0, perm=[[[[[[1]]]]]]).",
        // Unicode + control characters.
        "Transformations to apply: TilеSize, Раrallel, \u{0000}Unroll.",
        // Enormous list.
        &format!("Transformations to apply: {}.", vec!["Unroll"; 5000].join(", ")),
        // Empty and whitespace.
        "",
        "Transformations to apply: .",
        "Transformations to apply:",
        // No list at all.
        "Reasoning: I refuse to answer.",
    ];
    let program = WorkloadId::DeepSeekMoe.build_test();
    let mut rng = Pcg::new(1);
    let mut stats = FallbackStats::default();
    for text in hostile {
        let parsed = proposal::parse_response(text);
        let (seq, _fb) = proposal::resolve(&parsed, &program, &mut rng, &mut stats);
        // Whatever survived must be applicable without panicking.
        let sched = reasoning_compiler::schedule::Schedule::new(program.clone());
        let (out, _) = sched.apply_all(&seq);
        out.current.validate().unwrap();
    }
}

#[test]
fn hostile_transform_parameters_error_not_panic() {
    let p = WorkloadId::FluxConv.build_test();
    let hostile = [
        Transform::TileSize { stage: usize::MAX, loop_idx: 0, factor: 2 },
        Transform::TileSize { stage: 0, loop_idx: usize::MAX, factor: 2 },
        Transform::TileSize { stage: 0, loop_idx: 0, factor: i64::MAX },
        Transform::TileSize { stage: 0, loop_idx: 0, factor: -8 },
        Transform::Reorder { stage: 0, perm: vec![usize::MAX; 6] },
        Transform::Reorder { stage: 0, perm: vec![] },
        Transform::Fuse { stage: 0, loop_idx: usize::MAX - 1 },
        Transform::ComputeLocation { stage: 0, depth: usize::MAX },
        Transform::Vectorize { stage: 0, loop_idx: usize::MAX },
    ];
    for t in hostile {
        assert!(t.apply(&p).is_err(), "{t:?} should be rejected");
    }
}

#[test]
fn corrupt_manifests_error_cleanly() {
    use std::path::Path;
    let cases = [
        "",
        "{",
        "[]",
        r#"{"m": {}}"#,                                // missing file
        r#"{"m": {"file": "x.hlo.txt"}}"#,             // missing inputs
        r#"{"m": {"file": "x", "inputs": "nope", "outputs": []}}"#,
    ];
    for text in cases {
        assert!(
            Manifest::parse(Path::new("/tmp"), text).is_err(),
            "should reject: {text}"
        );
    }
}

#[test]
fn missing_artifact_file_fails_at_load_not_panic() {
    use std::path::Path;
    let m = Manifest::parse(
        Path::new("/tmp/definitely_missing_dir_rcc"),
        r#"{"ghost": {"file": "ghost.hlo.txt",
            "inputs": [{"shape": [2, 2], "dtype": "float32"}],
            "outputs": [{"shape": [2, 2], "dtype": "float32"}]}}"#,
    )
    .unwrap();
    if !cfg!(feature = "xla") {
        eprintln!("skipping: built without the xla feature");
        return;
    }
    let mut rt = reasoning_compiler::runtime::Runtime::cpu().unwrap();
    assert!(rt.load(&m, "ghost").is_err());
}

#[test]
fn wrong_input_payload_sizes_rejected() {
    let Ok(manifest) = Manifest::discover() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    if !cfg!(feature = "xla") {
        eprintln!("skipping: built without the xla feature");
        return;
    }
    let mut rt = reasoning_compiler::runtime::Runtime::cpu().unwrap();
    rt.load(&manifest, "deepseek_moe").unwrap();
    let exe = rt.get("deepseek_moe").unwrap();
    // Too few inputs.
    assert!(exe.run(&[vec![0.0; 16]]).is_err());
    // Wrong payload length.
    let mut inputs = exe.random_inputs(1);
    inputs[0].truncate(3);
    assert!(exe.run(&inputs).is_err());
}

#[test]
fn broken_configs_error_cleanly() {
    for text in [
        "strategy = ",               // missing value
        "[search\nbudget = 3",       // unterminated header
        "search.budget = \"NaN\"...",
    ] {
        assert!(Doc::parse(text).is_err(), "should reject: {text}");
    }
    // Unknown strategy/workload names fall back to defaults or panic at
    // lookup time with a clear message (not UB); here: unknown strategy
    // keeps the default.
    let doc = Doc::parse("[search]\nstrategy = \"quantum\"").unwrap();
    let cfg = TuneConfig::from_doc(&doc);
    assert_eq!(cfg.strategy, reasoning_compiler::coordinator::Strategy::LlmMcts);
}

#[test]
fn grounding_unknown_op_is_none() {
    let p = WorkloadId::Llama4Mlp.build_test();
    let mut rng = Pcg::new(2);
    assert!(proposal::ground("NotAnOp", &p, &mut rng).is_none());
}

#[test]
fn parse_response_bracket_bomb_terminates_quickly() {
    let bomb = format!(
        "Transformations to apply: {}{}",
        "Reorder(stage=0, perm=[".repeat(2000),
        "]".repeat(2000)
    );
    let start = std::time::Instant::now();
    let parsed = proposal::parse_response(&bomb);
    assert!(start.elapsed().as_secs_f64() < 1.0, "parser too slow on bomb");
    // Everything here is malformed one way or another.
    assert!(parsed
        .iter()
        .all(|p| matches!(p, Parsed::Invalid(_) | Parsed::Bare(_))));
}

// ---------------------------------------------------------------------------
// Deterministic fault injection: armed plans, retry/degrade, quarantine,
// and kill-at-step-N -> `--resume` bit-identity. These live here (not in
// lib unit tests) because fault state is process-global; `GUARD` keeps
// armed tests from interleaving.
// ---------------------------------------------------------------------------

fn result_key(r: &SearchResult) -> (u64, usize, Vec<(usize, u64)>) {
    (
        r.best_latency.to_bits(),
        r.samples_used,
        r.curve.iter().map(|m| (m.sample, m.latency.to_bits())).collect(),
    )
}

fn session_keys(s: &reasoning_compiler::coordinator::SessionResult) -> Vec<(u64, usize, Vec<(usize, u64)>)> {
    s.runs.iter().map(result_key).collect()
}

fn temp_journal(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!(
        "rcc_fi_journal_{tag}_{}_{}.jsonl",
        std::process::id(),
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos()
    ))
}

#[test]
fn arming_publishes_plan_and_crash_clock_is_deterministic() {
    let _g = GUARD.lock().unwrap_or_else(|p| p.into_inner());
    faults::disarm();
    assert!(!faults::armed());
    assert!(faults::plan().is_none());
    assert!(!faults::measure_fault(0), "disarmed sites never fire");
    assert_eq!(faults::steps(), 0, "disarmed sites never advance the clock");

    let plan = FaultPlan::parse("llm_error=0.25,measure_fail=0.5,crash_at_step=5,seed=11").unwrap();
    faults::arm(&plan);
    assert!(faults::armed());
    assert_eq!(faults::plan(), Some(plan.clone()));
    assert!(faults::crash_armed());
    assert!(!faults::crash_due(), "clock starts at zero on arm");

    let first: Vec<bool> = (0..16).map(|t| faults::measure_fault(t)).collect();
    assert_eq!(faults::steps(), 16);
    assert!(faults::crash_due(), "16 steps >= crash_at_step=5");
    assert!(first.iter().any(|&b| b) && first.iter().any(|&b| !b));

    // Re-arming the same plan resets the clock and replays identical
    // decisions: rolls are stateless in (seed, site, token).
    faults::arm(&plan);
    assert_eq!(faults::steps(), 0);
    assert!(!faults::crash_due());
    let second: Vec<bool> = (0..16).map(|t| faults::measure_fault(t)).collect();
    assert_eq!(first, second);

    // A no-op plan disarms rather than arming a do-nothing schedule.
    faults::arm(&FaultPlan::default());
    assert!(!faults::armed());
    assert!(!faults::crash_armed());
    faults::disarm();
}

#[test]
fn flaky_llm_engine_retries_then_degrades_without_aborting() {
    let _g = GUARD.lock().unwrap_or_else(|p| p.into_inner());
    faults::disarm();
    let cfg = TuneConfig {
        strategy: Strategy::LlmMcts,
        budget: 30,
        repeats: 2,
        ..Default::default()
    };
    // Moderately flaky engine: retries happen, the session still finishes.
    let flaky = FaultPlan::parse("llm_error=0.5,llm_timeout=0.1,seed=5").unwrap();
    faults::arm(&flaky);
    let a = run_session(&cfg).unwrap();
    faults::arm(&flaky); // reset the step clock for an identical replay
    let b = run_session(&cfg).unwrap();
    faults::disarm();
    assert_eq!(a.runs.len(), 2);
    assert!(a.llm_costs.retries > 0, "a 50% flaky engine must trigger retries");
    assert!(a.llm_costs.backoff_ms > 0, "retries schedule deterministic backoff");
    // Same plan seed -> bit-identical results and identical accounting.
    assert_eq!(session_keys(&a), session_keys(&b));
    assert_eq!(a.llm_costs.retries, b.llm_costs.retries);
    assert_eq!(a.llm_costs.degraded, b.llm_costs.degraded);
    assert_eq!(a.llm_costs.calls, b.llm_costs.calls);

    // An engine that is down almost always: calls exhaust their retry
    // budget and degrade to the sampler fallback, but tuning completes.
    let storm = FaultPlan::parse("llm_error=0.95,seed=5").unwrap();
    faults::arm(&storm);
    let c = run_session(&cfg).unwrap();
    faults::disarm();
    assert_eq!(c.runs.len(), 2, "degraded calls must not abort the session");
    assert!(c.llm_costs.degraded > 0, "0.95^3 per call must abandon some calls");
    assert!(c.mean_speedup() > 1.0, "fallback sampling still makes progress");
}

#[test]
fn measurement_quarantine_is_worker_invariant() {
    let _g = GUARD.lock().unwrap_or_else(|p| p.into_inner());
    faults::disarm();
    let plan = FaultPlan::parse("measure_fail=0.2,seed=9").unwrap();
    let cfg = |workers: usize| TuneConfig {
        strategy: Strategy::Mcts,
        budget: 40,
        repeats: 2,
        workers,
        ..Default::default()
    };
    faults::arm(&plan);
    let a = run_session(&cfg(1)).unwrap();
    faults::arm(&plan);
    let b = run_session(&cfg(4)).unwrap();
    faults::disarm();
    assert!(
        a.total_failed_measurements() > 0,
        "a 20% failure rate over 80 samples must quarantine something"
    );
    assert_eq!(
        session_keys(&a),
        session_keys(&b),
        "quarantine decisions are plan-time seeded: worker count must not matter"
    );
    assert_eq!(a.total_failed_measurements(), b.total_failed_measurements());
    // Quarantined samples are spent, not refunded.
    for r in &a.runs {
        assert!(r.samples_used <= 40);
        assert!(r.best_latency.is_finite(), "sentinel must never become the best");
    }

    // Evolutionary search folds failures as zero fitness and survives too.
    faults::arm(&plan);
    let es = run_session(&TuneConfig {
        strategy: Strategy::Evolutionary,
        budget: 40,
        repeats: 1,
        workers: 2,
        ..Default::default()
    })
    .unwrap();
    faults::disarm();
    assert!(es.runs[0].best_latency.is_finite());
    assert!(es.mean_speedup() >= 1.0);
}

#[test]
fn kill_at_step_then_resume_is_bit_identical() {
    let _g = GUARD.lock().unwrap_or_else(|p| p.into_inner());
    faults::disarm();
    for (fault_seed, shared_cache, workers) in [(3u64, false, 0), (11, true, 4)] {
        let base =
            FaultPlan::parse(&format!("measure_fail=0.1,seed={fault_seed}")).unwrap();
        let cfg = TuneConfig {
            strategy: Strategy::Mcts,
            budget: 30,
            repeats: 3,
            share_repeat_cache: shared_cache,
            workers,
            ..Default::default()
        };

        // Reference: the same measurement-fault plan, never killed.
        faults::arm(&base);
        let reference = run_session(&cfg).unwrap();

        // Killed run: crash after 35 measurement steps, i.e. mid-repeat 1.
        // The session journals completed repeats, then dies loudly.
        let jp = temp_journal(&format!("kill_{fault_seed}"));
        let mut jcfg = cfg.clone();
        jcfg.journal_path = Some(jp.to_string_lossy().to_string());
        let killer = FaultPlan { crash_at_step: Some(35), ..base.clone() };
        faults::arm(&killer);
        let err = run_session(&jcfg).unwrap_err();
        assert!(
            format!("{err:#}").contains("injected crash"),
            "kill must surface as the injected crash, got: {err:#}"
        );

        // Resume without the crash knob: journaled repeats replay verbatim,
        // the discarded one re-runs from its fixed seed — bit-identical.
        let mut rcfg = cfg.clone();
        rcfg.resume_from = Some(jp.to_string_lossy().to_string());
        faults::arm(&base);
        let resumed = run_session(&rcfg).unwrap();
        faults::disarm();
        assert!(
            resumed.resumed_repeats >= 1 && resumed.resumed_repeats < cfg.repeats,
            "crash at step 35 lands mid-session, got {} resumed",
            resumed.resumed_repeats
        );
        assert_eq!(
            session_keys(&reference),
            session_keys(&resumed),
            "resume (seed={fault_seed}, shared_cache={shared_cache}) must be \
             bit-identical to the uninterrupted session"
        );
        // Re-run repeats were re-checkpointed: the journal is now complete.
        let (_, entries) = SessionJournal::load(&jp).unwrap();
        assert_eq!(entries.len(), cfg.repeats);
        std::fs::remove_file(&jp).ok();
    }
}
