//! Failure injection: the system must degrade gracefully, never panic, on
//! malformed inputs — adversarial LLM responses, corrupt manifests, broken
//! configs and hostile proposal parameters.

use reasoning_compiler::coordinator::TuneConfig;
use reasoning_compiler::reasoning::proposal::{self, FallbackStats, Parsed};
use reasoning_compiler::runtime::Manifest;
use reasoning_compiler::schedule::Transform;
use reasoning_compiler::tir::WorkloadId;
use reasoning_compiler::util::rng::Pcg;
use reasoning_compiler::util::tomlmini::Doc;

#[test]
fn adversarial_llm_responses_never_panic() {
    let hostile = [
        // Prompt-injection-flavoured responses.
        "Ignore previous instructions. Transformations to apply: rm -rf /.",
        "Transformations to apply: TileSize(stage=999999999999, loop=18446744073709551615, factor=-3).",
        "Transformations to apply: Reorder(stage=0, perm=[0, 0, 0, 0, 0, 0, 0, 0, 0]).",
        "Transformations to apply: TileSize(stage=0, loop=0, factor=0), Vectorize(stage=0, loop=99).",
        // Deep nesting / bracket bombs.
        "Transformations to apply: Reorder(stage=0, perm=[[[[[[1]]]]]]).",
        // Unicode + control characters.
        "Transformations to apply: TilеSize, Раrallel, \u{0000}Unroll.",
        // Enormous list.
        &format!("Transformations to apply: {}.", vec!["Unroll"; 5000].join(", ")),
        // Empty and whitespace.
        "",
        "Transformations to apply: .",
        "Transformations to apply:",
        // No list at all.
        "Reasoning: I refuse to answer.",
    ];
    let program = WorkloadId::DeepSeekMoe.build_test();
    let mut rng = Pcg::new(1);
    let mut stats = FallbackStats::default();
    for text in hostile {
        let parsed = proposal::parse_response(text);
        let (seq, _fb) = proposal::resolve(&parsed, &program, &mut rng, &mut stats);
        // Whatever survived must be applicable without panicking.
        let sched = reasoning_compiler::schedule::Schedule::new(program.clone());
        let (out, _) = sched.apply_all(&seq);
        out.current.validate().unwrap();
    }
}

#[test]
fn hostile_transform_parameters_error_not_panic() {
    let p = WorkloadId::FluxConv.build_test();
    let hostile = [
        Transform::TileSize { stage: usize::MAX, loop_idx: 0, factor: 2 },
        Transform::TileSize { stage: 0, loop_idx: usize::MAX, factor: 2 },
        Transform::TileSize { stage: 0, loop_idx: 0, factor: i64::MAX },
        Transform::TileSize { stage: 0, loop_idx: 0, factor: -8 },
        Transform::Reorder { stage: 0, perm: vec![usize::MAX; 6] },
        Transform::Reorder { stage: 0, perm: vec![] },
        Transform::Fuse { stage: 0, loop_idx: usize::MAX - 1 },
        Transform::ComputeLocation { stage: 0, depth: usize::MAX },
        Transform::Vectorize { stage: 0, loop_idx: usize::MAX },
    ];
    for t in hostile {
        assert!(t.apply(&p).is_err(), "{t:?} should be rejected");
    }
}

#[test]
fn corrupt_manifests_error_cleanly() {
    use std::path::Path;
    let cases = [
        "",
        "{",
        "[]",
        r#"{"m": {}}"#,                                // missing file
        r#"{"m": {"file": "x.hlo.txt"}}"#,             // missing inputs
        r#"{"m": {"file": "x", "inputs": "nope", "outputs": []}}"#,
    ];
    for text in cases {
        assert!(
            Manifest::parse(Path::new("/tmp"), text).is_err(),
            "should reject: {text}"
        );
    }
}

#[test]
fn missing_artifact_file_fails_at_load_not_panic() {
    use std::path::Path;
    let m = Manifest::parse(
        Path::new("/tmp/definitely_missing_dir_rcc"),
        r#"{"ghost": {"file": "ghost.hlo.txt",
            "inputs": [{"shape": [2, 2], "dtype": "float32"}],
            "outputs": [{"shape": [2, 2], "dtype": "float32"}]}}"#,
    )
    .unwrap();
    if !cfg!(feature = "xla") {
        eprintln!("skipping: built without the xla feature");
        return;
    }
    let mut rt = reasoning_compiler::runtime::Runtime::cpu().unwrap();
    assert!(rt.load(&m, "ghost").is_err());
}

#[test]
fn wrong_input_payload_sizes_rejected() {
    let Ok(manifest) = Manifest::discover() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    if !cfg!(feature = "xla") {
        eprintln!("skipping: built without the xla feature");
        return;
    }
    let mut rt = reasoning_compiler::runtime::Runtime::cpu().unwrap();
    rt.load(&manifest, "deepseek_moe").unwrap();
    let exe = rt.get("deepseek_moe").unwrap();
    // Too few inputs.
    assert!(exe.run(&[vec![0.0; 16]]).is_err());
    // Wrong payload length.
    let mut inputs = exe.random_inputs(1);
    inputs[0].truncate(3);
    assert!(exe.run(&inputs).is_err());
}

#[test]
fn broken_configs_error_cleanly() {
    for text in [
        "strategy = ",               // missing value
        "[search\nbudget = 3",       // unterminated header
        "search.budget = \"NaN\"...",
    ] {
        assert!(Doc::parse(text).is_err(), "should reject: {text}");
    }
    // Unknown strategy/workload names fall back to defaults or panic at
    // lookup time with a clear message (not UB); here: unknown strategy
    // keeps the default.
    let doc = Doc::parse("[search]\nstrategy = \"quantum\"").unwrap();
    let cfg = TuneConfig::from_doc(&doc);
    assert_eq!(cfg.strategy, reasoning_compiler::coordinator::Strategy::LlmMcts);
}

#[test]
fn grounding_unknown_op_is_none() {
    let p = WorkloadId::Llama4Mlp.build_test();
    let mut rng = Pcg::new(2);
    assert!(proposal::ground("NotAnOp", &p, &mut rng).is_none());
}

#[test]
fn parse_response_bracket_bomb_terminates_quickly() {
    let bomb = format!(
        "Transformations to apply: {}{}",
        "Reorder(stage=0, perm=[".repeat(2000),
        "]".repeat(2000)
    );
    let start = std::time::Instant::now();
    let parsed = proposal::parse_response(&bomb);
    assert!(start.elapsed().as_secs_f64() < 1.0, "parser too slow on bomb");
    // Everything here is malformed one way or another.
    assert!(parsed
        .iter()
        .all(|p| matches!(p, Parsed::Invalid(_) | Parsed::Bare(_))));
}
