//! Integration tests of the observability plane (PR 6): the determinism
//! contract (tracing on vs off is bit-identical, at any worker count),
//! Chrome trace validity from a real traced search, and executor steal
//! accounting under an imbalanced batch.
//!
//! The recorder is process-global, so every test that enables or drains
//! it serializes on one mutex and leaves the recorder disabled+drained.

use std::collections::BTreeMap;
use std::sync::Mutex;

use reasoning_compiler::coordinator::{run_session, Strategy, TuneConfig};
use reasoning_compiler::cost::{HardwareModel, Platform, SurrogateModel};
use reasoning_compiler::obs;
use reasoning_compiler::report::explain::Explanation;
use reasoning_compiler::search::{
    EvoConfig, EvolutionaryStrategy, MctsConfig, MctsStrategy, RandomPolicy, SearchContext,
    SearchResult, SearchStrategy,
};
use reasoning_compiler::tir::workload::WorkloadId;
use reasoning_compiler::tir::Program;
use reasoning_compiler::util::executor::Executor;
use reasoning_compiler::util::json::Json;

static GUARD: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    // A panicking test must not wedge the others; the recorder state is
    // re-initialized at the top of each test anyway.
    GUARD.lock().unwrap_or_else(|p| p.into_inner())
}

struct Models {
    base: Program,
    platform: Platform,
    surrogate: SurrogateModel,
    hardware: HardwareModel,
}

fn models(workload: WorkloadId) -> Models {
    let platform = Platform::core_i9();
    Models {
        base: workload.build(),
        surrogate: SurrogateModel::new(platform.clone()),
        hardware: HardwareModel::new(platform.clone()),
        platform,
    }
}

fn mcts_run(m: &Models, budget: usize, seed: u64, workers: usize, eval_batch: usize) -> SearchResult {
    let mut ctx =
        SearchContext::new(&m.base, &m.surrogate, &m.hardware, &m.platform, budget, seed);
    ctx.executor = Executor::new(workers);
    ctx.eval_batch = eval_batch;
    let mut policy = RandomPolicy::new(seed);
    MctsStrategy::new(MctsConfig::default(), &mut policy).search(&ctx)
}

fn evo_run(m: &Models, budget: usize, seed: u64, workers: usize) -> SearchResult {
    let mut ctx =
        SearchContext::new(&m.base, &m.surrogate, &m.hardware, &m.platform, budget, seed);
    ctx.executor = Executor::new(workers);
    EvolutionaryStrategy::new(EvoConfig::default()).search(&ctx)
}

/// Everything a search result commits to, in bit-exact form.
fn result_key(r: &SearchResult) -> (u64, usize, Vec<(usize, u64)>) {
    (
        r.best_latency.to_bits(),
        r.samples_used,
        r.curve.iter().map(|m| (m.sample, m.latency.to_bits())).collect(),
    )
}

#[test]
fn tracing_on_off_is_bit_identical() {
    let _g = lock();
    obs::disable();
    obs::drain();
    let m = models(WorkloadId::DeepSeekMoe);
    for workers in [1usize, 4] {
        let eval_batch = if workers == 1 { 1 } else { 4 };
        let off_mcts = mcts_run(&m, 40, 7, workers, eval_batch);
        let off_evo = evo_run(&m, 60, 7, workers);

        obs::enable();
        let on_mcts = mcts_run(&m, 40, 7, workers, eval_batch);
        let on_evo = evo_run(&m, 60, 7, workers);
        obs::disable();
        let events = obs::drain();

        assert!(!events.is_empty(), "traced run must record events (workers={workers})");
        assert_eq!(
            result_key(&off_mcts),
            result_key(&on_mcts),
            "tracing changed MCTS results at workers={workers}"
        );
        assert_eq!(
            result_key(&off_evo),
            result_key(&on_evo),
            "tracing changed evolutionary results at workers={workers}"
        );
    }
}

#[test]
fn chrome_trace_export_is_well_formed() {
    let _g = lock();
    obs::disable();
    obs::drain();
    let m = models(WorkloadId::DeepSeekMoe);

    obs::enable();
    let _ = mcts_run(&m, 40, 3, 4, 4);
    obs::disable();
    let events = obs::drain();
    assert!(!events.is_empty(), "traced search produced no events");

    // Round-trip through serialized text, like `rcc trace summary` does.
    let text = obs::chrome_trace_json(&events).to_string();
    let doc = Json::parse(&text).expect("exporter emits parseable JSON");
    let entries = doc
        .get("traceEvents")
        .and_then(|v| v.as_arr())
        .expect("traceEvents array")
        .to_vec();
    assert!(!entries.is_empty());

    // Every B has a matching E on its thread, innermost-first, and
    // timestamps are monotone non-decreasing per thread.
    let mut stacks: BTreeMap<u64, Vec<String>> = BTreeMap::new();
    let mut last_ts: BTreeMap<u64, f64> = BTreeMap::new();
    for e in &entries {
        let tid = e.get("tid").and_then(Json::as_f64).expect("tid") as u64;
        let ts = e.get("ts").and_then(Json::as_f64).expect("ts");
        let name = e.get("name").and_then(Json::as_str).expect("name").to_string();
        assert!(
            *last_ts.get(&tid).unwrap_or(&0.0) <= ts,
            "timestamps regress on tid {tid}"
        );
        last_ts.insert(tid, ts);
        match e.get("ph").and_then(Json::as_str).expect("ph") {
            "B" => stacks.entry(tid).or_default().push(name),
            "E" => {
                let top = stacks.entry(tid).or_default().pop();
                assert_eq!(top.as_deref(), Some(name.as_str()), "E must close innermost B");
            }
            "i" => {}
            other => panic!("unexpected ph {other:?}"),
        }
    }
    for (tid, st) in &stacks {
        assert!(st.is_empty(), "unclosed B events on tid {tid}: {st:?}");
    }

    // The summary parser reads the same document back and sees the
    // measurement phase plus the embedded executor counters.
    let sum = obs::summarize_json(&doc).expect("summarizable trace");
    assert_eq!(sum.events, entries.len());
    assert!(sum.rows.iter().any(|r| r.kind == obs::EventKind::Measure), "no measure spans");
    assert!(sum.rows.iter().any(|r| r.kind == obs::EventKind::Select), "no select spans");
    assert!(sum.exec.is_some(), "executor counters missing from otherData");
    let rendered = obs::render_summary(&sum);
    assert!(rendered.contains("measure"));
    assert!(rendered.contains("executor:"));
}

fn temp_audit(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!(
        "rcc_audit_test_{tag}_{}_{}.jsonl",
        std::process::id(),
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos()
    ))
}

fn kind_count(records: &[Json], kind: &str) -> usize {
    records
        .iter()
        .filter(|r| r.get("kind").and_then(Json::as_str) == Some(kind))
        .count()
}

#[test]
fn audit_on_off_is_bit_identical() {
    // The decision log, like tracing, is strictly write-only: arming it
    // must not perturb a single bit of any search result, at any worker
    // count. Calibration is always-on and must agree too.
    let _g = lock();
    obs::disable();
    obs::drain();
    obs::audit::disarm();
    let m = models(WorkloadId::DeepSeekMoe);
    for workers in [1usize, 4] {
        let eval_batch = if workers == 1 { 1 } else { 4 };
        let off_mcts = mcts_run(&m, 40, 7, workers, eval_batch);
        let off_evo = evo_run(&m, 60, 7, workers);

        let path = temp_audit(&format!("parity_w{workers}"));
        let path_s = path.to_string_lossy().to_string();
        obs::audit::arm(&path_s).unwrap();
        let on_mcts = mcts_run(&m, 40, 7, workers, eval_batch);
        let on_evo = evo_run(&m, 60, 7, workers);
        obs::audit::disarm();

        assert_eq!(
            result_key(&off_mcts),
            result_key(&on_mcts),
            "audit changed MCTS results at workers={workers}"
        );
        assert_eq!(
            result_key(&off_evo),
            result_key(&on_evo),
            "audit changed evolutionary results at workers={workers}"
        );
        assert_eq!(off_mcts.calibration, on_mcts.calibration);
        assert_eq!(off_evo.calibration, on_evo.calibration);

        let records = obs::audit::load(&path_s).unwrap();
        assert!(kind_count(&records, "node") > 1, "MCTS emitted node records");
        assert!(kind_count(&records, "select") > 0, "MCTS emitted select records");
        assert!(kind_count(&records, "backprop") > 0, "MCTS emitted backprop records");
        assert!(kind_count(&records, "gen") > 0, "ES emitted generation records");
        assert!(kind_count(&records, "measure") > 0, "measure records carry calibration pairs");
        std::fs::remove_file(&path).ok();
    }
}

#[test]
fn audit_on_off_bit_identical_with_shared_repeat_cache() {
    let _g = lock();
    obs::audit::disarm();
    let cfg = TuneConfig {
        strategy: Strategy::Mcts,
        budget: 25,
        repeats: 2,
        workers: 4,
        share_repeat_cache: true,
        ..Default::default()
    };
    let off = run_session(&cfg).unwrap();
    let path = temp_audit("shared");
    let path_s = path.to_string_lossy().to_string();
    obs::audit::arm(&path_s).unwrap();
    let on = run_session(&cfg).unwrap();
    obs::audit::disarm();
    assert_eq!(
        off.runs.iter().map(result_key).collect::<Vec<_>>(),
        on.runs.iter().map(result_key).collect::<Vec<_>>(),
        "audit changed a shared-cache session"
    );
    assert_eq!(off.telemetry.calibration, on.telemetry.calibration);
    // The telemetry JSON block carries calibration + dropped-event counts.
    let tj = on.telemetry.to_json().to_string();
    assert!(tj.contains("\"calibration\""), "{tj}");
    assert!(tj.contains("\"dropped_events\""), "{tj}");
    std::fs::remove_file(&path).ok();
}

#[test]
fn explain_reconstructs_a_fixed_seed_session() {
    let _g = lock();
    obs::audit::disarm();
    let path = temp_audit("explain");
    let path_s = path.to_string_lossy().to_string();
    let cfg = TuneConfig {
        strategy: Strategy::LlmMcts,
        budget: 40,
        repeats: 2,
        ..Default::default()
    };
    let off = run_session(&cfg).unwrap();
    obs::audit::arm(&path_s).unwrap();
    let on = run_session(&cfg).unwrap();
    obs::audit::disarm();
    assert_eq!(
        off.runs.iter().map(result_key).collect::<Vec<_>>(),
        on.runs.iter().map(result_key).collect::<Vec<_>>()
    );

    let records = obs::audit::load(&path_s).unwrap();
    let ex = Explanation::from_records(&records);
    assert_eq!(ex.header.strategy, "llm_mcts");
    assert_eq!(ex.header.workload, "deepseek_moe");
    assert_eq!(ex.runs.len(), 2, "one result record per repeat");

    // The winning path reaches the run's best latency, and the marginal
    // reward attribution over its edges accounts for the whole
    // baseline-to-best improvement.
    let win = ex
        .runs
        .iter()
        .min_by(|a, b| a.best_latency.partial_cmp(&b.best_latency).unwrap())
        .unwrap();
    assert_eq!(win.seed, ex.winning_seed);
    assert!(!ex.path.is_empty(), "winning path reconstructed from the log alone");
    assert!(ex.path.iter().any(|p| !p.transforms.is_empty()));
    let attributed: f64 = ex.path.iter().map(|p| p.improvement).sum();
    let total = win.baseline - win.best_latency;
    assert!(
        (attributed - total).abs() <= 1e-9 * win.baseline.max(1.0),
        "attribution {attributed} != total improvement {total}"
    );

    assert!(ex.llm.calls > 0, "LLM strategy must leave llm records");
    assert!(ex.llm.offered > 0);
    assert!(ex.llm.acceptance_rate() > 0.0);
    assert_eq!(ex.calibration.len(), 1);
    assert!(ex.calibration[0].2.n > 0, "calibration table populated");

    // Golden shape of the human report (what CI greps for).
    let text = ex.render();
    for needle in ["session:", "winning path", "llm proposals", "calibration [", "sample efficiency"] {
        assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
    }
    let json = ex.to_json().to_string();
    assert!(json.contains("\"winning_path\""));
    std::fs::remove_file(&path).ok();
}

#[test]
fn explain_handles_es_sessions_via_generations() {
    let _g = lock();
    obs::audit::disarm();
    let path = temp_audit("es");
    let path_s = path.to_string_lossy().to_string();
    let cfg = TuneConfig {
        strategy: Strategy::Evolutionary,
        budget: 60,
        repeats: 1,
        ..Default::default()
    };
    obs::audit::arm(&path_s).unwrap();
    let s = run_session(&cfg).unwrap();
    obs::audit::disarm();
    assert!(s.telemetry.calibration.n > 0, "ES sessions calibrate too");

    let records = obs::audit::load(&path_s).unwrap();
    let ex = Explanation::from_records(&records);
    assert!(ex.path.is_empty(), "no tree to reconstruct for ES");
    assert!(!ex.generations.is_empty(), "generation table from gen records");
    assert!(ex.calibration.first().map(|c| c.2.n > 0).unwrap_or(false));
    assert!(ex.render().contains("es generations"));
    std::fs::remove_file(&path).ok();
}

#[test]
fn executor_stats_observe_steals_under_imbalance() {
    // Steal timing is inherently racy, so retry a few times; with a batch
    // this imbalanced a 4-wide pool essentially always steals at least
    // once. The accounting identity must hold on every attempt.
    let mut saw_steal = false;
    for _attempt in 0..5 {
        let exec = Executor::new(4);
        let n = 32usize;
        let results = exec.run(
            (0..n)
                .map(|i| {
                    move || {
                        // Every 8th task is ~ms-scale; the rest are instant,
                        // so their home deques drain and workers go stealing.
                        if i % 8 == 0 {
                            std::thread::sleep(std::time::Duration::from_millis(5));
                        }
                        i * 2
                    }
                })
                .collect(),
        );
        assert_eq!(results, (0..n).map(|i| i * 2).collect::<Vec<_>>());
        let stats = exec.stats();
        assert_eq!(
            stats.total_own_pops() + stats.total_steals(),
            n as u64,
            "every dispatched task is popped exactly once"
        );
        if stats.total_steals() >= 1 {
            saw_steal = true;
            break;
        }
    }
    assert!(saw_steal, "no steal observed in 5 runs of an imbalanced batch");
}
