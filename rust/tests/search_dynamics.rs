//! Integration test: the paper's headline comparative dynamics.
//!
//! Reproduces the qualitative claims of Figure 3 / Table 1 in miniature:
//! LLM-guided MCTS reaches higher speedups with fewer samples than both
//! vanilla MCTS and Evolutionary Search.

use reasoning_compiler::cost::{HardwareModel, Platform, SurrogateModel};
use reasoning_compiler::reasoning::{LlmPolicy, ModelProfile, SimulatedLlm};
use reasoning_compiler::search::{
    evolutionary_search, mcts_search, EvoConfig, MctsConfig, RandomPolicy, SearchResult,
};
use reasoning_compiler::tir::workload::WorkloadId;
use reasoning_compiler::util::stats;

fn run_three(
    workload: WorkloadId,
    platform: &Platform,
    budget: usize,
    seed: u64,
) -> (SearchResult, SearchResult, SearchResult) {
    let base = workload.build();
    let surrogate = SurrogateModel::new(platform.clone());
    let hardware = HardwareModel::new(platform.clone());
    let cfg = MctsConfig::default();

    let es = evolutionary_search(
        &base,
        &surrogate,
        &hardware,
        &EvoConfig::default(),
        platform,
        budget,
        seed,
    );
    let mut rand_policy = RandomPolicy::new(seed);
    let mcts = mcts_search(
        &base, &mut rand_policy, &surrogate, &hardware, &cfg, platform, budget, seed,
    );
    let engine = SimulatedLlm::new(ModelProfile::gpt4o_mini(), seed);
    let mut llm_policy = LlmPolicy::new(engine, 2, seed);
    let rc = mcts_search(
        &base, &mut llm_policy, &surrogate, &hardware, &cfg, platform, budget, seed,
    );
    (es, mcts, rc)
}

#[test]
fn reasoning_compiler_dominates_at_low_budget() {
    // Mean over a few seeds to smooth stochastic variation, as the paper
    // averages 20 repeats.
    let plat = Platform::core_i9();
    let mut es_early = Vec::new();
    let mut mcts_early = Vec::new();
    let mut rc_early = Vec::new();
    for seed in 1..=5 {
        let (es, mcts, rc) = run_three(WorkloadId::DeepSeekMoe, &plat, 72, seed);
        es_early.push(es.speedup_at(36));
        mcts_early.push(mcts.speedup_at(36));
        rc_early.push(rc.speedup_at(36));
    }
    let (es_m, mcts_m, rc_m) = (
        stats::mean(&es_early),
        stats::mean(&mcts_early),
        stats::mean(&rc_early),
    );
    eprintln!("speedup@36: ES {es_m:.2} | MCTS {mcts_m:.2} | RC {rc_m:.2}");
    assert!(
        rc_m > es_m,
        "RC ({rc_m:.2}x) must beat ES ({es_m:.2}x) at 36 samples"
    );
    assert!(
        rc_m > mcts_m,
        "RC ({rc_m:.2}x) must beat vanilla MCTS ({mcts_m:.2}x) at 36 samples"
    );
}

#[test]
fn rc_reaches_es_final_quality_with_fewer_samples() {
    let plat = Platform::core_i9();
    let mut reductions = Vec::new();
    for seed in 11..=13 {
        let (es, _, rc) = run_three(WorkloadId::Llama4Mlp, &plat, 150, seed);
        let target = es.best_speedup();
        if let Some(n) = rc.samples_to_reach(target) {
            reductions.push(es.samples_used as f64 / n as f64);
        } else {
            reductions.push(1.0); // did not reach: no reduction credit
        }
    }
    let mean_reduction = stats::mean(&reductions);
    eprintln!("sample reduction to ES-final quality: {mean_reduction:.1}x");
    assert!(
        mean_reduction > 1.5,
        "RC should need fewer samples than ES (got {mean_reduction:.1}x)"
    );
}

#[test]
fn all_strategies_beat_baseline_on_every_workload() {
    let plat = Platform::xeon_e3();
    for w in WorkloadId::ALL {
        let (es, mcts, rc) = run_three(w, &plat, 50, 2);
        assert!(es.best_speedup() > 1.0, "{}: ES {}", w.name(), es.best_speedup());
        assert!(mcts.best_speedup() > 1.0, "{}: MCTS {}", w.name(), mcts.best_speedup());
        assert!(rc.best_speedup() > 1.0, "{}: RC {}", w.name(), rc.best_speedup());
    }
}
