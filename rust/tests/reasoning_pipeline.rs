//! Integration tests of the full reasoning pipeline:
//! prompt -> simulated LLM -> parse -> validate -> ground -> apply,
//! across model profiles and history depths, plus the ablation directions
//! the paper claims (§4.3).

use reasoning_compiler::coordinator::{run_session, Strategy, TuneConfig};
use reasoning_compiler::cost::Platform;
use reasoning_compiler::reasoning::engine::LlmEngine;
use reasoning_compiler::reasoning::{proposal, ModelProfile, PromptContext, SimulatedLlm};
use reasoning_compiler::schedule::Schedule;
use reasoning_compiler::tir::WorkloadId;
use reasoning_compiler::util::rng::Pcg;
use reasoning_compiler::util::stats;

#[test]
fn every_model_produces_parseable_applicable_proposals() {
    let plat = Platform::core_i9();
    let node = Schedule::new(WorkloadId::DeepSeekMoe.build());
    for model in ModelProfile::all() {
        let mut engine = SimulatedLlm::new(model.clone(), 11);
        let mut rng = Pcg::new(12);
        let mut stats_ = proposal::FallbackStats::default();
        let mut applied_any = 0;
        let rounds = 30;
        for _ in 0..rounds {
            let ctx = PromptContext {
                node: &node,
                ancestors: vec![],
                scores: vec![1.0],
                platform: &plat,
                exemplars: &[],
            };
            let resp = engine.complete(&ctx);
            assert!(resp.text.contains("Transformations to apply:"), "{}", model.name);
            let parsed = proposal::parse_response(&resp.text);
            assert!(!parsed.is_empty(), "{}: no proposals parsed", model.name);
            let (seq, _fb) = proposal::resolve(&parsed, &node.current, &mut rng, &mut stats_);
            let (out, applied) = node.apply_all(&seq);
            if applied > 0 {
                applied_any += 1;
                out.current.validate().unwrap();
            }
        }
        // Even the weakest model must usually produce something applicable.
        assert!(
            applied_any as f64 / rounds as f64 > 0.5,
            "{}: only {applied_any}/{rounds} rounds applicable",
            model.name
        );
    }
}

#[test]
fn model_quality_orders_early_speedup() {
    // Fig. 4(a) direction: the 70B profile converges faster than the 7B one
    // at a small budget (averaged over repeats).
    let mk = |model: &str| TuneConfig {
        strategy: Strategy::LlmMcts,
        workload: "llama3_attention".to_string(),
        platform: "core_i9".to_string(),
        budget: 40,
        repeats: 6,
        model: model.to_string(),
        ..Default::default()
    };
    let strong = run_session(&mk("llama33_70b")).expect("session").mean_speedup_at(36);
    let weak = run_session(&mk("ds_distill_7b")).expect("session").mean_speedup_at(36);
    assert!(
        strong > weak,
        "70B ({strong:.2}x) should beat 7B ({weak:.2}x) at 36 samples"
    );
}

#[test]
fn deeper_history_does_not_hurt() {
    // Fig. 4(b) direction: parent+gp+ggp >= parent+gp (within tolerance),
    // averaged across seeds.
    let mk = |depth: usize, seed: u64| TuneConfig {
        strategy: Strategy::LlmMcts,
        workload: "deepseek_moe".to_string(),
        platform: "core_i9".to_string(),
        budget: 60,
        repeats: 4,
        history_depth: depth,
        seed,
        ..Default::default()
    };
    let mut d2 = Vec::new();
    let mut d3 = Vec::new();
    for seed in [1, 2, 3] {
        d2.push(run_session(&mk(2, seed)).expect("session").mean_speedup());
        d3.push(run_session(&mk(3, seed)).expect("session").mean_speedup());
    }
    let (m2, m3) = (stats::mean(&d2), stats::mean(&d3));
    assert!(
        m3 > m2 * 0.9,
        "deeper context should not materially hurt: depth2 {m2:.2}x vs depth3 {m3:.2}x"
    );
}

#[test]
fn fallback_rates_reproduce_table8_bands() {
    // Run enough expansions per model and check the measured all-invalid
    // fallback rate lands in the paper's band.
    let bands: [(&str, f64, f64); 4] = [
        ("gpt4o_mini", 0.0, 0.0001),
        ("llama33_70b", 0.0, 0.02),
        ("llama31_8b", 0.04, 0.25),
        ("ds_distill_7b", 0.08, 0.32),
    ];
    for (model, lo, hi) in bands {
        let cfg = TuneConfig {
            strategy: Strategy::LlmMcts,
            workload: "deepseek_moe".to_string(),
            budget: 120,
            repeats: 3,
            model: model.to_string(),
            ..Default::default()
        };
        let s = run_session(&cfg).expect("session");
        let rate = s.llm_fallback_rate;
        assert!(
            (lo..=hi).contains(&rate),
            "{model}: fallback {rate:.4} outside [{lo}, {hi}]"
        );
    }
}

#[test]
fn token_costs_scale_with_budget() {
    let mk = |budget: usize| TuneConfig {
        strategy: Strategy::LlmMcts,
        workload: "flux_conv".to_string(),
        budget,
        repeats: 2,
        ..Default::default()
    };
    let small = run_session(&mk(20)).expect("session");
    let large = run_session(&mk(80)).expect("session");
    assert!(large.llm_costs.prompt_tokens > small.llm_costs.prompt_tokens * 2);
    let model = ModelProfile::gpt4o_mini();
    assert!(large.llm_costs.usd(&model) > small.llm_costs.usd(&model));
}

#[test]
fn prompt_embeds_everything_the_engine_uses() {
    // Information-hygiene check: the rendered prompt must contain the
    // program text, history, scores, platform header and op list — i.e. a
    // real API model would receive the same information the simulated
    // analyst consumes.
    let plat = Platform::graviton2();
    let base = Schedule::new(WorkloadId::FluxConv.build());
    let child = {
        let mut rng = Pcg::new(4);
        let analysis = reasoning_compiler::cost::AnalysisCache::new();
        let (seq, _) = reasoning_compiler::reasoning::engine::informed_proposals(
            &base,
            &plat,
            &Default::default(),
            &analysis,
            &mut rng,
        );
        base.apply_all(&seq).0
    };
    let ctx = PromptContext {
        node: &child,
        ancestors: vec![&base],
        scores: vec![0.8, 0.4],
        platform: &plat,
        exemplars: &[],
    };
    let text = reasoning_compiler::reasoning::prompt::render(&ctx);
    assert!(text.contains("Amazon Graviton2"));
    assert!(text.contains("T.block(\"conv2d\")"));
    assert!(text.contains("Applied transformation history"));
    assert!(text.contains("Current: 0.800"));
    assert!(text.contains("Parent: 0.400"));
    for op in ["TileSize", "Reorder", "Fuse", "Parallel", "Vectorize", "Unroll"] {
        assert!(text.contains(op), "prompt missing op {op}");
    }
}
