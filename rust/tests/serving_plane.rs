//! Integration contracts for the continuous-batching serving plane:
//! bounded queues under overload, no hostage-taking of short requests,
//! determinism of the seeded load generator across executor widths, and
//! scheduling isolation from background (low-priority) tuning load.
//!
//! Everything here runs on the simulated backend: scheduling decisions
//! live on the virtual tick clock, so the admission/eviction/batch
//! sequence — and the virtual latency reservoirs — are bit-deterministic
//! per load seed regardless of worker count or wall-clock noise.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use reasoning_compiler::coordinator::{ServeError, Server, ServerConfig};
use reasoning_compiler::coordinator::server::synthetic_work;
use reasoning_compiler::util::executor::{Executor, Priority};

fn models() -> Vec<String> {
    vec!["deepseek_moe".to_string(), "llama4_mlp".to_string()]
}

#[test]
fn overload_backpressure_keeps_queues_bounded() {
    let cfg = ServerConfig { queue_cap: 4, target_delay_ticks: 4096, ..Default::default() };
    let mut server = Server::start_sim(&models(), cfg).unwrap();
    let mut admitted = 0;
    let mut rejected = 0;
    for i in 0..40 {
        match server.try_submit("deepseek_moe", i) {
            Ok(()) => admitted += 1,
            Err(ServeError::Overloaded { model, depth }) => {
                assert_eq!(model, "deepseek_moe");
                assert_eq!(depth, 4, "rejection happens exactly at the budget");
                rejected += 1;
            }
            Err(e) => panic!("unexpected error: {e}"),
        }
    }
    assert_eq!(admitted, 4, "budget clamps to queue_cap");
    assert_eq!(rejected, 36);
    assert!(server.pending() <= 4, "no queue ever exceeds its bound");
    let mm = &server.metrics.per_model["deepseek_moe"];
    assert_eq!((mm.admitted, mm.rejected), (4, 36));
    // The queue drains normally after the overload burst.
    server.drain().unwrap();
    assert_eq!(server.metrics.total_requests(), 4);
}

#[test]
fn short_requests_are_not_held_hostage_by_a_long_batch() {
    // One long request (50-tick service) shares the slot pool with a
    // stream of short ones (2-tick service). Under fixed batching the
    // shorts would queue behind the long batch; with per-slot continuous
    // batching they flow through the remaining slots immediately.
    let cfg = ServerConfig { max_batch: 4, ..Default::default() };
    let mut server = Server::start_sim(&models(), cfg).unwrap();
    server.set_service_ticks("deepseek_moe", 2).unwrap();
    server.set_service_ticks("llama4_mlp", 50).unwrap();
    server.try_submit("llama4_mlp", 0).unwrap();
    for i in 0..12 {
        server.try_submit("deepseek_moe", 1 + i).unwrap();
    }
    server.drain().unwrap();
    let short = &server.metrics.per_model["deepseek_moe"];
    let long = &server.metrics.per_model["llama4_mlp"];
    assert_eq!(short.requests, 12);
    assert_eq!(long.requests, 1);
    // Every short completed while the long request was still in flight:
    // even the slowest short is far below the long service time.
    let short_worst = short
        .request_latencies
        .samples()
        .iter()
        .cloned()
        .fold(0.0f64, f64::max);
    let long_latency = long.request_latencies.samples()[0];
    assert!(
        short_worst < long_latency / 2.0,
        "short worst-case {short_worst} should be far below the long request's {long_latency}"
    );
}

/// Deterministic digest of everything the load generator decided.
fn decision_digest(server: &Server) -> Vec<(String, u64, u64, u64, u64, u64, u64, Vec<u64>)> {
    server
        .metrics
        .per_model
        .iter()
        .map(|(m, s)| {
            (
                m.clone(),
                s.admitted,
                s.rejected,
                s.evicted,
                s.requests,
                s.batches,
                s.partial_dispatches,
                s.request_latencies.samples().iter().map(|v| v.to_bits()).collect(),
            )
        })
        .collect()
}

#[test]
fn seeded_load_generator_is_deterministic_across_worker_counts() {
    let run = |workers: usize| {
        let exec = Executor::new(workers);
        let cfg = ServerConfig { queue_cap: 8, arrival_burst: 3, ..Default::default() };
        let mut server = Server::start_sim(&models(), cfg)
            .unwrap()
            .with_executor(exec, 2_000);
        server.run_synthetic(300, 9).unwrap();
        decision_digest(&server)
    };
    let serial = run(1);
    let wide = run(4);
    assert_eq!(serial, wide, "admission/eviction/batch composition must not depend on workers");
    // And per-seed stability: the same seed replays the same decisions.
    assert_eq!(serial, run(1));
}

#[test]
fn overloaded_generator_rejects_deterministically() {
    let run = |workers: usize| {
        let exec = Executor::new(workers);
        // Tiny queues + aggressive bursts: the generator must shed load.
        let cfg = ServerConfig { queue_cap: 2, arrival_burst: 6, ..Default::default() };
        let mut server = Server::start_sim(&models(), cfg)
            .unwrap()
            .with_executor(exec, 2_000);
        server.run_synthetic(300, 5).unwrap();
        decision_digest(&server)
    };
    let a = run(1);
    let b = run(4);
    assert_eq!(a, b);
    let total_rejected: u64 = a.iter().map(|r| r.2).sum();
    assert!(total_rejected > 0, "saturating bursts must trip admission control");
}

#[test]
fn background_low_priority_load_does_not_change_serving_decisions() {
    // A saturating flood of low-priority work (a stand-in for `--tune`)
    // shares the executor with the serving plane. High-priority serve
    // dispatch preempts it at every dequeue/steal site; the virtual-clock
    // decision sequence must be bit-identical to a quiet executor's.
    let quiet = {
        let exec = Executor::new(2);
        let mut server = Server::start_sim(&models(), ServerConfig::default())
            .unwrap()
            .with_executor(exec, 2_000);
        server.run_synthetic(200, 11).unwrap();
        decision_digest(&server)
    };
    let noisy = {
        let exec = Executor::new(2);
        let stop = Arc::new(AtomicBool::new(false));
        let flood_exec = Arc::clone(&exec);
        let flood_stop = Arc::clone(&stop);
        let flood = std::thread::spawn(move || {
            while !flood_stop.load(Ordering::Relaxed) {
                let tasks: Vec<_> =
                    (0..16).map(|_| || synthetic_work(20_000)).collect();
                flood_exec.run_with(Priority::Low, tasks);
            }
        });
        let mut server = Server::start_sim(&models(), ServerConfig::default())
            .unwrap()
            .with_executor(Arc::clone(&exec), 2_000);
        server.run_synthetic(200, 11).unwrap();
        stop.store(true, Ordering::Relaxed);
        flood.join().unwrap();
        decision_digest(&server)
    };
    assert_eq!(quiet, noisy);
}
