//! Stress tests of the persistent work-stealing executor (PR 5) at full
//! coordinator depth: repeats × `eval_batch` × `tune_models` nested on one
//! shared executor must (a) complete without deadlock or oversubscription
//! pathologies, (b) produce bit-identical results to the fully serial
//! path, and (c) fail loudly — a panicking task fails its submitting
//! group instead of hanging the pool.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};

use reasoning_compiler::coordinator::{run_session, tune_models, Strategy, TuneConfig};
use reasoning_compiler::search::SearchResult;
use reasoning_compiler::util::executor::Executor;

fn curve_key(r: &SearchResult) -> Vec<(usize, u64)> {
    r.curve.iter().map(|m| (m.sample, m.latency.to_bits())).collect()
}

/// repeats × eval_batch nested on one session executor: a wide executor
/// must reproduce the serial session bit-for-bit (latencies, curves,
/// sample counts, cache accounting).
#[test]
fn nested_repeats_and_eval_batch_match_serial_bit_for_bit() {
    let base = TuneConfig {
        strategy: Strategy::Mcts,
        budget: 25,
        repeats: 3,
        eval_batch: 2,
        ..Default::default()
    };
    let serial = run_session(&TuneConfig { workers: 1, ..base.clone() }).unwrap();
    for workers in [4, 8] {
        let wide = run_session(&TuneConfig { workers, ..base.clone() }).unwrap();
        assert_eq!(serial.runs.len(), wide.runs.len());
        for (s, w) in serial.runs.iter().zip(&wide.runs) {
            assert_eq!(s.best_latency.to_bits(), w.best_latency.to_bits(), "workers={workers}");
            assert_eq!(curve_key(s), curve_key(w), "workers={workers}");
            assert_eq!(s.samples_used, w.samples_used, "workers={workers}");
            assert_eq!(
                (s.cache_hits, s.cache_misses),
                (w.cache_hits, w.cache_misses),
                "workers={workers}"
            );
        }
    }
}

/// The full serve-fleet nest — tune_models × repeats × eval_batch, all on
/// one shared executor plus the shared measurement pool — against the
/// serial executor. Distinct workloads keep the pool deterministic, so
/// the whole fleet must be bit-identical at every width.
#[test]
fn tune_models_fleet_is_bit_identical_across_executor_widths() {
    let models = vec![
        "deepseek_moe".to_string(),
        "llama4_mlp".to_string(),
        "not_a_workload".to_string(), // skipped, never an error
    ];
    let mk = |workers: usize| TuneConfig {
        strategy: Strategy::Mcts,
        budget: 20,
        repeats: 2,
        eval_batch: 2,
        workers,
        db_path: None,
        ..Default::default()
    };
    let serial = tune_models(&models, &mk(1)).unwrap();
    assert_eq!(serial.sessions.len(), 2, "unknown model skipped");
    let wide = tune_models(&models, &mk(8)).unwrap();
    assert_eq!(serial.sessions.len(), wide.sessions.len());
    for ((ms, ss), (mw, sw)) in serial.sessions.iter().zip(&wide.sessions) {
        assert_eq!(ms, mw, "model order is input order");
        for (a, b) in ss.runs.iter().zip(&sw.runs) {
            assert_eq!(a.best_latency.to_bits(), b.best_latency.to_bits(), "{ms}");
            assert_eq!(curve_key(a), curve_key(b), "{ms}");
            assert_eq!(a.samples_used, b.samples_used, "{ms}");
        }
    }
    assert_eq!(serial.pool_entries, wide.pool_entries, "pool content is deterministic");
    assert_eq!(serial.pooled_hits, wide.pooled_hits);
    assert!(serial.pool_entries > 0, "sessions write their measurements into the pool");
}

/// Models aliasing one workload share a session — the aliased fingerprints
/// are measured once, and both aliases report the identical session.
#[test]
fn aliased_models_share_one_session_and_one_measurement_set() {
    let models = vec!["deepseek_moe".to_string(), "deepseek_moe".to_string()];
    let fleet = tune_models(
        &models,
        &TuneConfig {
            strategy: Strategy::Mcts,
            budget: 15,
            repeats: 1,
            workers: 4,
            db_path: None,
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(fleet.sessions.len(), 2, "both aliases are reported");
    let (a, b) = (&fleet.sessions[0].1, &fleet.sessions[1].1);
    assert_eq!(a.runs.len(), b.runs.len());
    for (ra, rb) in a.runs.iter().zip(&b.runs) {
        assert_eq!(ra.best_latency.to_bits(), rb.best_latency.to_bits());
        assert_eq!(ra.samples_used, rb.samples_used);
    }
    // One session's worth of samples, not two: the alias consumed zero.
    let total: usize = a.runs.iter().map(|r| r.samples_used).sum();
    assert!(total <= 15, "aliased model must not re-measure: {total}");
}

/// A panicking task fails the submitting group (the panic propagates to
/// the waiter) and leaves the executor fully usable — it must never hang
/// the pool or poison the worker threads.
#[test]
fn panicking_task_fails_the_group_and_spares_the_executor() {
    let exec = Executor::new(4);
    let exec_ref = &exec;
    let completed = AtomicUsize::new(0);
    let completed_ref = &completed;

    let outcome = catch_unwind(AssertUnwindSafe(|| {
        let tasks: Vec<Box<dyn FnOnce() -> usize + Send>> = (0..8)
            .map(|i| {
                let b: Box<dyn FnOnce() -> usize + Send> = Box::new(move || {
                    if i == 5 {
                        panic!("injected failure in task {i}");
                    }
                    completed_ref.fetch_add(1, Ordering::SeqCst);
                    i
                });
                b
            })
            .collect();
        exec_ref.run(tasks)
    }));
    assert!(outcome.is_err(), "the group must re-raise the task panic");

    // The pool survives: a fresh (even nested) group still completes and
    // still folds deterministically by submission index.
    let nested: Vec<usize> = exec.run(
        (0..6usize)
            .map(|i| {
                move || {
                    exec_ref
                        .run((0..4usize).map(|j| move || i * 10 + j).collect::<Vec<_>>())
                        .into_iter()
                        .sum::<usize>()
                }
            })
            .collect(),
    );
    let expect: Vec<usize> =
        (0..6).map(|i| (0..4).map(|j| i * 10 + j).sum::<usize>()).collect();
    assert_eq!(nested, expect);
}

/// A panic inside a *session* (nested two groups deep) surfaces as a
/// panic from the outer call, not a hang — exercised through the public
/// coordinator API by tuning with a budget that makes the strategy panic
/// impossible, then injecting the panic at the executor layer directly
/// under coordinator-shaped nesting.
#[test]
fn nested_group_panic_propagates_outward() {
    let exec = Executor::new(3);
    let exec_ref = &exec;
    let outcome = catch_unwind(AssertUnwindSafe(|| {
        let outer: Vec<Box<dyn FnOnce() -> usize + Send>> = vec![
            Box::new(move || {
                // Inner group: one member panics mid-fleet.
                let tasks: Vec<Box<dyn FnOnce() -> usize + Send>> = vec![
                    Box::new(|| 1),
                    Box::new(|| panic!("inner repeat failure")),
                ];
                exec_ref.run(tasks).into_iter().sum::<usize>()
            }),
            Box::new(move || 7usize),
        ];
        exec_ref.run(outer)
    }));
    assert!(outcome.is_err(), "inner-group panic must reach the outer waiter");
    let after: Vec<Box<dyn FnOnce() -> usize + Send>> =
        vec![Box::new(|| 41usize), Box::new(|| 1usize)];
    assert_eq!(exec.run(after), vec![41, 1]);
}
