//! Integration tests of the ANN transfer index (PR 7).
//!
//! The acceptance properties: retrieval through the index returns exactly
//! what the exact linear scan returns on stock-scale databases (small
//! partitions are searched exhaustively, so recall is 1.0 by
//! construction); below the record-count threshold the scan path is used
//! outright; tuning sessions are bit-identical with the index on or off
//! and across worker counts; record aging never ranks a superseded
//! record above the fresher work that superseded it; and the `<db>.idx`
//! sidecar persists across processes, reloads when fresh, and is
//! silently rebuilt when stale or corrupt.

use std::path::{Path, PathBuf};

use reasoning_compiler::coordinator::{run_session_on, Strategy, TuneConfig};
use reasoning_compiler::db::{shape_class, workload_fingerprint, Database, TuningRecord};
use reasoning_compiler::schedule::Transform;
use reasoning_compiler::tir::workload;
use reasoning_compiler::transfer::{find_matches, sidecar_path, uses_index, workload_extents};
use reasoning_compiler::util::Pcg;

fn temp_db(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!(
        "rcc_tindex_{tag}_{}_{}.jsonl",
        std::process::id(),
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos()
    ))
}

/// A real record for an MoE matmul shape: genuine fingerprint, shape
/// class and extents, and a trace that replays on any multiple-of-4 `j`.
fn moe_rec(tokens: i64, out_dim: i64, in_dim: i64, latency: f64, ts: u64) -> TuningRecord {
    let prog = workload::moe_matmul("idx_src", tokens, out_dim, in_dim);
    TuningRecord {
        workload_fp: workload_fingerprint(&prog),
        workload: format!("moe_{tokens}x{out_dim}x{in_dim}"),
        platform: "core_i9".to_string(),
        strategy: "test".to_string(),
        trace: vec![Transform::TileSize { stage: 0, loop_idx: 1, factor: 4 }],
        latency,
        baseline_latency: 10.0,
        seed: 1,
        timestamp: ts,
        shape_class: shape_class(&prog),
        extents: workload_extents(&prog),
    }
}

fn target() -> reasoning_compiler::tir::Program {
    workload::moe_matmul("idx_target", 16, 256, 128)
}

/// Flatten a match list into a comparable signature.
fn signature(db: &Database, k: usize) -> Vec<(u64, u64, bool, u64)> {
    find_matches(db, &target(), "core_i9", k)
        .iter()
        .map(|m| {
            (
                m.record.workload_fp,
                m.record.timestamp,
                m.superseded,
                m.distance.to_bits(),
            )
        })
        .collect()
}

fn populate(path: &Path, records: &[TuningRecord]) {
    let mut db = Database::open(path).unwrap();
    for r in records {
        db.add(r.clone());
    }
    db.commit().unwrap();
}

#[test]
fn index_retrieval_matches_the_exact_scan_bit_for_bit() {
    let path = temp_db("parity");
    let mut records = Vec::new();
    let mut ts = 0u64;
    // Several shapes in the target's class, multiple records per shape
    // (some superseded), plus another platform and a foreign kernel.
    for (t, o, i) in [
        (16i64, 512i64, 256i64),
        (32, 512, 512),
        (8, 1024, 256),
        (16, 2048, 512),
        (32, 256, 128),
    ] {
        for (lat, step) in [(4.0, 0u64), (2.5, 1), (3.0, 2)] {
            ts += 1;
            records.push(moe_rec(t, o, i, lat, ts + step));
        }
    }
    let mut other = moe_rec(16, 512, 256, 1.0, 999);
    other.platform = "graviton2".to_string();
    records.push(other);
    populate(&path, &records);

    // Handle 1: plain scan. Handle 2: index forced on (threshold 0).
    let scan_db = Database::open(&path).unwrap();
    assert!(!uses_index(&scan_db));
    let mut ix_db = Database::open(&path).unwrap();
    ix_db.attach_transfer_index(0);
    assert!(uses_index(&ix_db), "threshold 0 must engage the index");

    for k in [1, 3, 8, 64] {
        assert_eq!(
            signature(&scan_db, k),
            signature(&ix_db, k),
            "index and scan must agree at k={k}"
        );
    }
    // The superseded flag surfaces: the (4.0, earliest) record of each
    // shape is dominated by the fresher 2.5.
    let matches = find_matches(&ix_db, &target(), "core_i9", 64);
    assert!(matches.iter().any(|m| m.superseded));
    assert!(matches.iter().any(|m| !m.superseded));

    std::fs::remove_file(sidecar_path(&path)).ok();
    std::fs::remove_file(&path).ok();
}

#[test]
fn below_threshold_the_scan_path_is_used() {
    let path = temp_db("threshold");
    populate(&path, &[moe_rec(16, 512, 256, 2.0, 1), moe_rec(32, 512, 512, 3.0, 2)]);
    let mut db = Database::open(&path).unwrap();
    db.attach_transfer_index(256);
    assert!(
        !uses_index(&db),
        "2 records < threshold 256 must stay on the exact scan"
    );
    // Retrieval still works (through the scan path).
    assert!(!find_matches(&db, &target(), "core_i9", 4).is_empty());
    std::fs::remove_file(sidecar_path(&path)).ok();
    std::fs::remove_file(&path).ok();
}

#[test]
fn aging_never_ranks_a_superseded_record_above_its_dominator() {
    let mut rng = Pcg::new(0xA61);
    let shapes = [
        (8i64, 128i64, 128i64),
        (16, 256, 256),
        (32, 512, 256),
        (16, 512, 512),
        (8, 256, 128),
        (32, 1024, 512),
    ];
    for round in 0..10 {
        let path = temp_db(&format!("aging_{round}"));
        let mut records = Vec::new();
        for &(t, o, i) in &shapes {
            for _ in 0..(1 + rng.gen_range(3)) {
                let latency = 1.0 + 9.0 * rng.gen_f64();
                let ts = 1 + rng.gen_range(50) as u64;
                records.push(moe_rec(t, o, i, latency, ts));
            }
        }
        populate(&path, &records);

        let scan_db = Database::open(&path).unwrap();
        let mut ix_db = Database::open(&path).unwrap();
        ix_db.attach_transfer_index(0);
        assert_eq!(
            signature(&scan_db, 64),
            signature(&ix_db, 64),
            "round {round}: scan/index parity under random aging"
        );

        // Within one workload fingerprint every fresh match must precede
        // every superseded one: same extents => same base distance, and
        // the staleness penalty strictly separates them.
        let matches = find_matches(&ix_db, &target(), "core_i9", 64);
        for (a, m1) in matches.iter().enumerate() {
            for m2 in matches.iter().skip(a + 1) {
                if m1.record.workload_fp == m2.record.workload_fp {
                    assert!(
                        !(m1.superseded && !m2.superseded),
                        "round {round}: superseded record ranked above its dominator"
                    );
                }
            }
        }
        std::fs::remove_file(sidecar_path(&path)).ok();
        std::fs::remove_file(&path).ok();
    }
}

#[test]
fn sessions_are_bit_identical_with_index_on_or_off() {
    let path = temp_db("session");
    let db_str = path.to_string_lossy().to_string();
    // Seed the database with prior work on a structurally similar shape.
    let cfg_seed = TuneConfig {
        strategy: Strategy::LlmMcts,
        budget: 60,
        repeats: 1,
        seed: 42,
        db_path: Some(db_str.clone()),
        workers: 1,
        ..Default::default()
    };
    run_session_on(&workload::moe_matmul("idx_seed", 32, 512, 256), &cfg_seed)
        .expect("seed session");

    let b = target();
    let cfg_off = TuneConfig {
        strategy: Strategy::Mcts,
        budget: 40,
        repeats: 1,
        seed: 9,
        db_path: Some(db_str.clone()),
        transfer_index: false,
        workers: 1,
        ..Default::default()
    };
    // Index forced on at any size: exact parity, so identical sessions.
    let cfg_forced = TuneConfig {
        transfer_index: true,
        transfer_index_threshold: 0,
        ..cfg_off.clone()
    };
    let curve = |s: &reasoning_compiler::coordinator::SessionResult| -> Vec<(usize, u64)> {
        s.runs[0].curve.iter().map(|m| (m.sample, m.latency.to_bits())).collect()
    };
    let off = run_session_on(&b, &cfg_off).expect("scan session");
    let forced = run_session_on(&b, &cfg_forced).expect("index session");
    assert_eq!(off.runs[0].best_latency, forced.runs[0].best_latency);
    assert_eq!(off.runs[0].samples_used, forced.runs[0].samples_used);
    assert_eq!(curve(&off), curve(&forced));

    // And identical again across worker counts with the index engaged.
    let wide = run_session_on(&b, &TuneConfig { workers: 4, ..cfg_forced.clone() })
        .expect("parallel index session");
    assert_eq!(forced.runs[0].best_latency, wide.runs[0].best_latency);
    assert_eq!(forced.runs[0].samples_used, wide.runs[0].samples_used);
    assert_eq!(curve(&forced), curve(&wide));

    std::fs::remove_file(sidecar_path(&path)).ok();
    std::fs::remove_file(&path).ok();
}

#[test]
fn sidecar_persists_reloads_and_rebuilds_when_stale_or_corrupt() {
    let path = temp_db("sidecar");
    let mut records = Vec::new();
    for i in 0..12i64 {
        records.push(moe_rec(8 << (i % 3), 256, 128, 2.0 + i as f64, i as u64));
    }
    populate(&path, &records);

    // First attach builds the index and writes the sidecar.
    let mut db = Database::open(&path).unwrap();
    db.attach_transfer_index(0);
    let ix = db.transfer_index().expect("index attached");
    assert!(!ix.loaded_from_sidecar(), "first attach is a fresh build");
    let side = sidecar_path(&path);
    assert!(side.exists(), "attach must persist {}", side.display());

    // A second process loads it instead of rebuilding.
    let mut db2 = Database::open(&path).unwrap();
    db2.attach_transfer_index(0);
    assert!(db2.transfer_index().unwrap().loaded_from_sidecar());
    assert_eq!(signature(&db, 64), signature(&db2, 64));

    // Committing through another handle makes the sidecar stale; the next
    // attach detects the drift and rebuilds — never trusts a stale file.
    let mut writer = Database::open(&path).unwrap();
    writer.add(moe_rec(16, 1024, 512, 1.5, 99));
    writer.commit().unwrap();
    let mut db3 = Database::open(&path).unwrap();
    db3.attach_transfer_index(0);
    let ix3 = db3.transfer_index().unwrap();
    assert!(!ix3.loaded_from_sidecar(), "stale sidecar must be rebuilt");
    assert_eq!(ix3.covered(), db3.len());

    // Corruption is not fatal either: garbage in, rebuild out.
    std::fs::write(&side, b"{ not an index").unwrap();
    let mut db4 = Database::open(&path).unwrap();
    db4.attach_transfer_index(0);
    assert!(!db4.transfer_index().unwrap().loaded_from_sidecar());
    assert_eq!(signature(&db3, 64), signature(&db4, 64));

    std::fs::remove_file(&side).ok();
    std::fs::remove_file(&path).ok();
}

#[test]
fn pre_transfer_sentinel_records_are_excluded_with_one_count() {
    let path = temp_db("sentinel");
    let mut records = vec![moe_rec(16, 512, 256, 2.0, 1), moe_rec(32, 512, 512, 3.0, 2)];
    // Records written before the transfer metadata existed: shape_class 0,
    // no extents. They must be skipped (and counted), not indexed.
    for i in 0..3 {
        let mut r = moe_rec(16, 256, 128, 4.0 + i as f64, 10 + i);
        r.shape_class = 0;
        r.extents = Vec::new();
        records.push(r);
    }
    populate(&path, &records);

    let mut db = Database::open(&path).unwrap();
    db.attach_transfer_index(0);
    let ix = db.transfer_index().unwrap();
    assert_eq!(ix.sentinel_skipped(), 3);
    assert_eq!(ix.covered(), db.len(), "sentinels still count as covered");
    assert!(uses_index(&db));
    // Retrieval still serves the two real records.
    assert_eq!(find_matches(&db, &target(), "core_i9", 8).len(), 2);

    std::fs::remove_file(sidecar_path(&path)).ok();
    std::fs::remove_file(&path).ok();
}
