//! PJRT round-trip: load every AOT artifact, execute it on the rust CPU
//! client, and cross-check numerics against expectations. Requires
//! `make artifacts` (skips gracefully otherwise).

use reasoning_compiler::runtime::{Manifest, Runtime};

fn manifest_or_skip() -> Option<Manifest> {
    match Manifest::discover() {
        Ok(m) => Some(m),
        Err(_) => {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            None
        }
    }
}

#[test]
fn all_artifacts_compile_and_run() {
    let Some(manifest) = manifest_or_skip() else { return };
    if !cfg!(feature = "xla") {
        eprintln!("skipping: built without the xla feature");
        return;
    }
    let mut rt = Runtime::cpu().expect("PJRT CPU client");
    let n = rt.load_all(&manifest).expect("compile all artifacts");
    assert_eq!(n, manifest.artifacts.len());
    for name in manifest.artifacts.keys() {
        let exe = rt.get(name).unwrap();
        let inputs = exe.random_inputs(42);
        let out = exe.run(&inputs).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(out.outputs.len(), exe.spec.outputs.len(), "{name}");
        for (o, spec) in out.outputs.iter().zip(&exe.spec.outputs) {
            assert_eq!(o.len(), spec.elems(), "{name} output size");
            assert!(o.iter().all(|x| x.is_finite()), "{name} non-finite output");
        }
        assert!(out.latency_s > 0.0);
    }
}

#[test]
fn execution_is_deterministic() {
    let Some(manifest) = manifest_or_skip() else { return };
    if !cfg!(feature = "xla") {
        eprintln!("skipping: built without the xla feature");
        return;
    }
    let mut rt = Runtime::cpu().unwrap();
    rt.load(&manifest, "deepseek_moe").unwrap();
    let exe = rt.get("deepseek_moe").unwrap();
    let inputs = exe.random_inputs(7);
    let a = exe.run(&inputs).unwrap();
    let b = exe.run(&inputs).unwrap();
    assert_eq!(a.outputs, b.outputs);
}

#[test]
fn moe_artifact_matches_manual_top1_routing() {
    // Independent numeric check: with router logits forcing expert 0 and a
    // single non-zero input feature, the output equals that expert's
    // weight row.
    let Some(manifest) = manifest_or_skip() else { return };
    if !cfg!(feature = "xla") {
        eprintln!("skipping: built without the xla feature");
        return;
    }
    let mut rt = Runtime::cpu().unwrap();
    rt.load(&manifest, "deepseek_moe").unwrap();
    let exe = rt.get("deepseek_moe").unwrap();
    let spec = &exe.spec;
    let (tokens, d_in) = (spec.inputs[0].shape[0], spec.inputs[0].shape[1]);
    let (n_exp, _, d_out) = (
        spec.inputs[1].shape[0],
        spec.inputs[1].shape[1],
        spec.inputs[1].shape[2],
    );
    // x: token 0 has a 1.0 at feature 3, everything else zero.
    let mut x = vec![0f32; (tokens * d_in) as usize];
    x[3] = 1.0;
    // experts: w[e][k][j] = e + j*0.001 + k*0.01
    let mut w = vec![0f32; (n_exp * d_in * d_out) as usize];
    for e in 0..n_exp {
        for k in 0..d_in {
            for j in 0..d_out {
                w[((e * d_in + k) * d_out + j) as usize] =
                    e as f32 + j as f32 * 0.001 + k as f32 * 0.01;
            }
        }
    }
    // router: all tokens to expert 0.
    let mut logits = vec![-10f32; (tokens * n_exp) as usize];
    for t in 0..tokens {
        logits[(t * n_exp) as usize] = 10.0;
    }
    let out = exe.run(&[x, w, logits]).unwrap();
    let y = &out.outputs[0];
    // Token 0: y[j] = w[0][3][j] = 0.001*j + 0.03.
    for j in 0..d_out.min(8) {
        let want = 0.001 * j as f32 + 0.03;
        let got = y[j as usize];
        assert!(
            (got - want).abs() < 1e-4,
            "y[{j}] = {got}, want {want}"
        );
    }
    // Token 1 (all-zero input): output 0.
    for j in 0..d_out.min(8) {
        assert!(y[(d_out + j) as usize].abs() < 1e-5);
    }
}
