//! Integration tests of the persistent tuning database: a cold `tune` run
//! followed by a warm-started run on the same workload must reach the cold
//! run's best speedup in strictly fewer hardware-model samples, with the
//! warm run reporting a nonzero measurement-cache hit count.

use std::path::PathBuf;

use reasoning_compiler::coordinator::{run_session, Strategy, TuneConfig};
use reasoning_compiler::cost::{HardwareModel, Platform, SurrogateModel};
use reasoning_compiler::db::{workload_fingerprint, Database, TuningRecord};
use reasoning_compiler::schedule::Schedule;
use reasoning_compiler::search::{evolutionary_search_warm, EvoConfig};
use reasoning_compiler::tir::WorkloadId;

fn temp_db(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!(
        "rcc_tdb_{tag}_{}_{}.jsonl",
        std::process::id(),
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .unwrap()
            .as_nanos()
    ))
}

#[test]
fn warm_run_reaches_cold_best_in_strictly_fewer_samples() {
    let db_path = temp_db("warm");
    let cfg = TuneConfig {
        strategy: Strategy::Mcts,
        workload: "deepseek_moe".to_string(),
        platform: "core_i9".to_string(),
        budget: 50,
        repeats: 1,
        seed: 42,
        db_path: Some(db_path.to_string_lossy().to_string()),
        ..Default::default()
    };

    // ---- cold run: empty database, every evaluation costs a sample --------
    let cold = run_session(&cfg).expect("cold session");
    let cold_run = &cold.runs[0];
    assert_eq!(cold_run.cache_hits, 0, "cold run has nothing to hit");
    assert!(cold_run.best_speedup() > 1.0, "cold run must improve");
    let cold_best = cold_run.best_speedup();
    let cold_samples = cold_run
        .samples_to_reach(cold_best)
        .expect("cold run reached its own best");
    assert!(cold_samples >= 1, "hardware measurements start at sample 1");

    // The session committed its records.
    let db = Database::open(&db_path).expect("reopen db");
    assert_eq!(db.len(), 1, "one record per repeat");
    let fp = workload_fingerprint(&WorkloadId::DeepSeekMoe.build());
    assert!(db.best(fp, "core_i9").is_some());

    // ---- warm run: seeded from the database -------------------------------
    // Same seed => identical baseline measurement, so "cold best speedup"
    // means the same latency target; the warm start replays the recorded
    // trace through the pre-populated cache before the first sample.
    let warm = run_session(&cfg).expect("warm session");
    let warm_run = &warm.runs[0];
    assert!(
        warm_run.cache_hits > 0,
        "warm run must report measurement-cache hits"
    );
    let warm_samples = warm_run
        .samples_to_reach(cold_best)
        .expect("warm run must reach the cold run's best speedup");
    assert!(
        warm_samples < cold_samples,
        "warm start must reach {cold_best:.2}x in fewer samples: \
         warm {warm_samples} vs cold {cold_samples}"
    );

    std::fs::remove_file(&db_path).ok();
}

#[test]
fn warm_evolutionary_search_reuses_recorded_measurements() {
    let base = WorkloadId::DeepSeekMoe.build();
    let plat = Platform::core_i9();
    let surrogate = SurrogateModel::new(plat.clone());
    let hardware = HardwareModel::new(plat.clone());

    // Record one known-good schedule by hand.
    let trace = vec![
        reasoning_compiler::schedule::Transform::TileSize { stage: 0, loop_idx: 2, factor: 64 },
        reasoning_compiler::schedule::Transform::Parallel { stage: 0, loop_idx: 0 },
    ];
    let sched = Schedule::new(base.clone());
    let (replayed, applied) = sched.apply_all(&trace);
    assert_eq!(applied, trace.len());
    use reasoning_compiler::cost::analytical::CostModel as _;
    let known_latency = hardware.latency(&replayed.current, 7);

    let mut db = Database::in_memory();
    db.add(TuningRecord {
        workload_fp: workload_fingerprint(&base),
        workload: base.name.clone(),
        platform: "core_i9".to_string(),
        strategy: "test".to_string(),
        trace,
        latency: known_latency,
        baseline_latency: known_latency * 4.0,
        seed: 7,
        timestamp: 1,
        shape_class: 0,
        extents: Vec::new(),
    });
    let (warm, cache) = db.hints(&base, "core_i9", 4);
    assert_eq!(warm.entries.len(), 1);

    // Measure the whole population every generation so the warm member is
    // guaranteed to be evaluated — through the cache, for free.
    let cfg = EvoConfig {
        population: 16,
        measure_per_gen: 16,
        ..Default::default()
    };
    let r = evolutionary_search_warm(
        &base, &surrogate, &hardware, &cfg, &plat, 40, 3,
        Some(&warm), Some(cache),
    );
    assert!(r.cache_hits > 0, "warm member must be answered by the cache");
    assert_eq!(r.samples_used, 40, "budget still fully spent on new candidates");
    assert!(
        r.best_latency <= known_latency,
        "search must be at least as good as the warm-started schedule"
    );
}

#[test]
fn warm_seeding_hits_at_sample_zero_and_cache_only_does_not() {
    // Distinguishes warm *seeding* from mere cache attachment. The recorded
    // trace needs 6 transforms (4 tiles + cache-write + parallel); an MCTS
    // expansion proposal applies at most 4, so the first measured candidate
    // of an unseeded search provably cannot match the recorded program.
    // Therefore: seeded run => first curve entry at sample 0 (free hit);
    // cache-only run => first curve entry at sample 1 (hardware measure).
    use reasoning_compiler::schedule::Transform;
    use reasoning_compiler::search::{mcts_search_warm, MctsConfig, RandomPolicy};

    let base = WorkloadId::DeepSeekMoe.build();
    let plat = Platform::core_i9();
    let surrogate = SurrogateModel::new(plat.clone());
    let hardware = HardwareModel::new(plat.clone());
    let trace = vec![
        Transform::TileSize { stage: 0, loop_idx: 1, factor: 64 },
        Transform::TileSize { stage: 0, loop_idx: 3, factor: 128 },
        Transform::TileSize { stage: 0, loop_idx: 0, factor: 4 },
        Transform::TileSize { stage: 0, loop_idx: 2, factor: 8 },
        Transform::CacheWrite { stage: 0 },
        Transform::Parallel { stage: 0, loop_idx: 0 },
    ];
    let (replayed, applied) = Schedule::new(base.clone()).apply_all(&trace);
    assert_eq!(applied, trace.len(), "hand-built trace must be legal");
    use reasoning_compiler::cost::analytical::CostModel as _;
    let known_latency = hardware.latency(&replayed.current, 9);

    let mut db = Database::in_memory();
    db.add(TuningRecord {
        workload_fp: workload_fingerprint(&base),
        workload: base.name.clone(),
        platform: "core_i9".to_string(),
        strategy: "test".to_string(),
        trace,
        latency: known_latency,
        baseline_latency: known_latency * 3.0,
        seed: 9,
        timestamp: 1,
        shape_class: 0,
        extents: Vec::new(),
    });
    let (warm, cache) = db.hints(&base, "core_i9", 4);
    assert_eq!(warm.entries.len(), 1);

    let run = |seed_warm: bool| {
        let mut policy = RandomPolicy::new(13);
        mcts_search_warm(
            &base,
            &mut policy,
            &surrogate,
            &hardware,
            &MctsConfig::default(),
            &plat,
            20,
            13,
            seed_warm.then_some(&warm),
            Some(cache.clone()),
        )
    };

    let seeded = run(true);
    assert!(seeded.cache_hits > 0, "seeded run answers the trace from cache");
    assert_eq!(
        seeded.curve[0].sample, 0,
        "seeded run's first evaluation is a free warm hit"
    );

    let unseeded = run(false);
    assert_eq!(
        unseeded.curve[0].sample, 1,
        "without seeding, the first evaluation must be a hardware sample"
    );
}

#[test]
fn empty_warm_start_is_identical_to_cold_search() {
    // A database with no matching records must not perturb the search:
    // same seed => byte-identical curves with and without an (empty) db.
    let db_path = temp_db("empty");
    let cfg_plain = TuneConfig {
        strategy: Strategy::Mcts,
        budget: 30,
        repeats: 1,
        seed: 11,
        ..Default::default()
    };
    let cfg_db = TuneConfig {
        db_path: Some(db_path.to_string_lossy().to_string()),
        ..cfg_plain.clone()
    };
    let plain = run_session(&cfg_plain).expect("plain");
    let with_db = run_session(&cfg_db).expect("with empty db");
    assert_eq!(plain.runs[0].best_latency, with_db.runs[0].best_latency);
    assert_eq!(plain.runs[0].curve.len(), with_db.runs[0].curve.len());
    std::fs::remove_file(&db_path).ok();
}
