//! PR-3 incremental-evaluation equivalence: the copy-on-write TIR, the
//! memoized per-stage hashes and the shared `AnalysisCache` are pure
//! plumbing — every observable value (simulated latency per seed,
//! program/workload fingerprints, extracted features, whole search
//! trajectories) must be **bit-identical** to a fresh deep-clone evaluated
//! with no caches at all. Uses the in-repo property harness
//! (`util::prop`) over random legal transform sequences.

use std::sync::Arc;

use reasoning_compiler::cost::{
    analytical, features, simulator, AnalysisCache, CostModel, HardwareModel, Platform,
    SurrogateModel,
};
use reasoning_compiler::db::{program_fingerprint, workload_fingerprint};
use reasoning_compiler::schedule::{sampler, Schedule, Transform};
use reasoning_compiler::search::{
    EvoConfig, EvolutionaryStrategy, MctsConfig, MctsStrategy, RandomPolicy, SearchContext,
    SearchResult, SearchStrategy,
};
use reasoning_compiler::tir::{Program, WorkloadId};
use reasoning_compiler::util::prop;

/// The pre-PR evaluation path: no analysis cache, plain `simulate`.
struct UncachedHardware {
    platform: Platform,
}

impl CostModel for UncachedHardware {
    fn latency(&self, program: &Program, seed: u64) -> f64 {
        simulator::simulate(program, &self.platform, seed)
    }
    fn name(&self) -> &'static str {
        "hardware-sim"
    }
}

/// The pre-PR surrogate path: no analysis cache, plain `predict`.
struct UncachedSurrogate {
    platform: Platform,
}

impl CostModel for UncachedSurrogate {
    fn latency(&self, program: &Program, seed: u64) -> f64 {
        analytical::predict(program, &self.platform, seed)
    }
    fn name(&self) -> &'static str {
        "surrogate"
    }
}

#[test]
fn cow_plus_memoized_path_bit_identical_to_fresh_deep_clone_uncached() {
    // One shared cache across every case: hits must never change values.
    let analysis = AnalysisCache::new();
    let plat = Platform::core_i9();
    for w in WorkloadId::ALL {
        prop::check(
            &format!("incremental-equivalence[{}]", w.name()),
            0xC0C0 ^ w.name().len() as u64,
            20,
            |rng| {
                let base = Schedule::new(w.build());
                let len = 1 + rng.gen_range(10);
                let seq = sampler::random_sequence(&base.current, len, rng);
                base.apply_all(&seq).0.trace.to_vec()
            },
            |trace| {
                let base = Schedule::new(w.build());
                let (sched, _) = base.apply_all(trace);
                let cow = &sched.current; // CoW chain, stage memos warm
                let fresh = cow.deep_clone(); // fresh allocations, memos cold

                if program_fingerprint(cow) != program_fingerprint(&fresh) {
                    return Err("program fingerprint differs from cold rehash".into());
                }
                if workload_fingerprint(cow) != workload_fingerprint(&fresh) {
                    return Err("workload fingerprint differs from cold rehash".into());
                }
                for seed in [0u64, 1, 5, 17] {
                    let cached = simulator::simulate_cached(cow, &plat, seed, &analysis);
                    let plain = simulator::simulate(&fresh, &plat, seed);
                    if cached.to_bits() != plain.to_bits() {
                        return Err(format!(
                            "simulate seed {seed}: cached {cached} != uncached {plain}"
                        ));
                    }
                    let pc = analytical::predict_cached(cow, &plat, seed, &analysis);
                    let pp = analytical::predict(&fresh, &plat, seed);
                    if pc.to_bits() != pp.to_bits() {
                        return Err(format!(
                            "predict seed {seed}: cached {pc} != uncached {pp}"
                        ));
                    }
                }
                if features::extract_cached(cow, &plat, &analysis) != features::extract(&fresh, &plat)
                {
                    return Err("features differ between cached and uncached".into());
                }
                Ok(())
            },
        );
    }
}

#[test]
fn cow_apply_shares_untouched_stages_and_buffers_across_siblings() {
    let s = Schedule::new(WorkloadId::Llama3Attention.build());
    let a = s.apply(Transform::Parallel { stage: 0, loop_idx: 0 }).unwrap();
    let b = s.apply(Transform::CacheWrite { stage: 0 }).unwrap();
    // Stage 1 was never touched: parent and both siblings share one
    // allocation (this is what makes MCTS's thousands of sibling schedules
    // O(stage) instead of O(program)).
    assert!(Arc::ptr_eq(&s.current.stages[1], &a.current.stages[1]));
    assert!(Arc::ptr_eq(&s.current.stages[1], &b.current.stages[1]));
    // The touched stage diverged.
    assert!(!Arc::ptr_eq(&s.current.stages[0], &a.current.stages[0]));
    assert!(!Arc::ptr_eq(&a.current.stages[0], &b.current.stages[0]));
    // The buffer table is immutable and shared by everyone.
    assert!(Arc::ptr_eq(&s.current.buffers, &a.current.buffers));
    assert!(Arc::ptr_eq(&s.current.buffers, &b.current.buffers));
    // Deeper edits on a sibling still leave the other untouched stage shared.
    let a2 = a.apply(Transform::CacheWrite { stage: 0 }).unwrap();
    assert!(Arc::ptr_eq(&s.current.stages[1], &a2.current.stages[1]));
}

fn curve_key(r: &SearchResult) -> Vec<(usize, u64)> {
    r.curve.iter().map(|m| (m.sample, m.latency.to_bits())).collect()
}

#[test]
fn searches_with_analysis_caches_match_uncached_models_bit_for_bit() {
    // Whole-trajectory proof: MCTS and ES driven by the cache-backed models
    // reproduce the exact curves of the uncached pre-PR evaluation path —
    // same latencies, same sample numbers, same best traces, per seed.
    let plat = Platform::core_i9();
    let base = WorkloadId::DeepSeekMoe.build();
    let shared = AnalysisCache::new();
    let cached_sur = SurrogateModel::with_analysis(plat.clone(), shared.share());
    let cached_hw = HardwareModel::with_analysis(plat.clone(), shared.share());
    let plain_sur = UncachedSurrogate { platform: plat.clone() };
    let plain_hw = UncachedHardware { platform: plat.clone() };

    for seed in [3u64, 11] {
        let run =
            |sur: &dyn CostModel, hw: &dyn CostModel| -> (SearchResult, SearchResult) {
                let ctx = SearchContext::new(&base, sur, hw, &plat, 40, seed);
                let mut policy = RandomPolicy::new(seed);
                let mcts = MctsStrategy::new(MctsConfig::default(), &mut policy).search(&ctx);
                let ctx = SearchContext::new(&base, sur, hw, &plat, 60, seed);
                let es = EvolutionaryStrategy::new(EvoConfig::default()).search(&ctx);
                (mcts, es)
            };
        let (mcts_cached, es_cached) = run(&cached_sur, &cached_hw);
        let (mcts_plain, es_plain) = run(&plain_sur, &plain_hw);
        assert_eq!(curve_key(&mcts_cached), curve_key(&mcts_plain), "mcts seed {seed}");
        assert_eq!(mcts_cached.best_trace, mcts_plain.best_trace, "mcts seed {seed}");
        assert_eq!(curve_key(&es_cached), curve_key(&es_plain), "es seed {seed}");
        assert_eq!(es_cached.best_trace, es_plain.best_trace, "es seed {seed}");
    }
    assert!(!shared.is_empty(), "the cached run must actually have cached analyses");
}
