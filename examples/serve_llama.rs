//! Serving example: dynamic-batching inference over the AOT artifacts.
//!
//! ```
//! make artifacts          # once: Python lowers the kernels to HLO text
//! cargo run --release --example serve_llama -- [requests] [max_batch]
//! ```
//! Loads every compiled layer (attention, MoE, conv, MLP and the full
//! Llama-3-style block) on the PJRT CPU client, drives a synthetic request
//! mix through the dynamic batcher, and reports latency/throughput — the
//! "efficient model serving" half of the paper's title. Python is not on
//! the request path: only the rust binary and libxla run here.

use reasoning_compiler::coordinator::{Server, ServerConfig};
use reasoning_compiler::runtime::Manifest;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let requests: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(96);
    let max_batch: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(8);

    let manifest = Manifest::discover()?;
    println!(
        "loading {} artifacts from {} ...",
        manifest.artifacts.len(),
        manifest.dir.display()
    );
    let mut server = Server::start(&manifest, ServerConfig { max_batch, ..Default::default() })?;

    println!("serving {requests} synthetic requests (max batch {max_batch})...\n");
    server.run_synthetic(requests, 7)?;

    println!("{}", server.metrics.report());

    // Focused latency check on the end-to-end block. Submissions go
    // through admission control now, so tick the scheduler as we go
    // rather than stacking the queue to its budget.
    for _ in 0..16 {
        server.submit("llama3_block", 99)?;
        server.step()?;
    }
    server.drain()?;
    let m = &server.metrics.per_model["llama3_block"];
    println!(
        "llama3_block: p50 {:.3} ms, p99 {:.3} ms over {} requests",
        m.p50() * 1e3,
        m.p99() * 1e3,
        m.requests
    );
    Ok(())
}
