//! Quickstart: the REASONING COMPILER in ~40 lines.
//!
//! ```
//! cargo run --release --example quickstart
//! ```
//! Builds the paper's running example (the DeepSeek-R1 MoE matmul from
//! Appendix A), shows its TIR, runs a short LLM-guided MCTS tuning session
//! on the simulated Intel Core i9, and prints the discovered schedule.

use reasoning_compiler::coordinator::{run_session, Strategy, TuneConfig};
use reasoning_compiler::schedule::Schedule;
use reasoning_compiler::tir::{printer, WorkloadId};

fn main() {
    // 1. A tunable tensor program (one TVM-style task).
    let workload = WorkloadId::DeepSeekMoe;
    let program = workload.build();
    println!("=== input program ===\n{}", printer::print_program(&program));

    // 2. Tune it: LLM-guided MCTS, paper defaults (c = sqrt2, B = 2,
    //    parent+grandparent context), 72-sample budget.
    let cfg = TuneConfig {
        strategy: Strategy::LlmMcts,
        workload: workload.name().to_string(),
        platform: "core_i9".to_string(),
        budget: 72,
        repeats: 3,
        ..Default::default()
    };
    let session = run_session(&cfg).expect("tuning session");
    println!(
        "mean speedup over pre-optimized code: {:.2}x (at 36 samples: {:.2}x)",
        session.mean_speedup(),
        session.mean_speedup_at(36)
    );

    // 3. Inspect the winning transformation sequence.
    let best_run = &session.runs[0];
    let (best, _) = Schedule::new(program).apply_all(&best_run.best_trace);
    println!("\n=== winning schedule ({:.2}x) ===", best_run.best_speedup());
    println!("{}", best.render_trace());
    println!("\n=== scheduled program ===\n{}", printer::print_program(&best.current));
}
