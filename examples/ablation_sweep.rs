//! Ablation sweep: model choice x trace depth x branching factor on one
//! benchmark — a compact version of §4.3 / Appendix C-E.
//!
//! ```
//! cargo run --release --example ablation_sweep -- [workload]
//! ```

use reasoning_compiler::coordinator::{run_session, Strategy, TuneConfig};
use reasoning_compiler::reasoning::ModelProfile;
use reasoning_compiler::tir::WorkloadId;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let workload = args.first().map(|s| s.as_str()).unwrap_or("llama3_attention");
    let w = WorkloadId::from_name(workload).expect("unknown workload");
    let base = TuneConfig {
        strategy: Strategy::LlmMcts,
        workload: w.name().to_string(),
        platform: "core_i9".to_string(),
        budget: 150,
        repeats: 3,
        ..Default::default()
    };

    println!("=== {} on Intel Core i9, 150-sample budget, 3 repeats ===\n", w.display());

    println!("--- Fig. 4(a): proposal model ---");
    println!("{:<30} {:>11} {:>11} {:>11}", "model", "speedup@18", "speedup@36", "speedup@150");
    for model in ModelProfile::all() {
        let cfg = TuneConfig { model: model.name.to_string(), ..base.clone() };
        let s = run_session(&cfg).expect("tuning session");
        println!(
            "{:<30} {:>10.2}x {:>10.2}x {:>10.2}x",
            model.display,
            s.mean_speedup_at(18),
            s.mean_speedup_at(36),
            s.mean_speedup_at(150)
        );
    }

    println!("\n--- Fig. 4(b): historical trace depth ---");
    for (label, depth) in [("parent+grandparent", 2), ("parent+gp+great-gp", 3)] {
        let cfg = TuneConfig { history_depth: depth, ..base.clone() };
        let s = run_session(&cfg).expect("tuning session");
        println!(
            "{:<30} {:>10.2}x {:>10.2}x {:>10.2}x",
            label,
            s.mean_speedup_at(18),
            s.mean_speedup_at(36),
            s.mean_speedup_at(150)
        );
    }

    println!("\n--- Appendix E: branching factor ---");
    for b in [2usize, 4] {
        let cfg = TuneConfig { branching: b, ..base.clone() };
        let s = run_session(&cfg).expect("tuning session");
        println!(
            "B = {b:<26} {:>10.2}x {:>10.2}x {:>10.2}x",
            s.mean_speedup_at(18),
            s.mean_speedup_at(36),
            s.mean_speedup_at(150)
        );
    }
}
