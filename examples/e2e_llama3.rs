//! END-TO-END DRIVER: the full system on a real small workload.
//!
//! ```
//! make artifacts
//! cargo run --release --example e2e_llama3
//! ```
//! Proves all layers compose (recorded in EXPERIMENTS.md §End-to-end):
//!
//! 1. **L3 search** — tune the end-to-end Llama-3-8B task set (QKV/O
//!    projections, attention, gated MLP) with both TVM-style Evolutionary
//!    Search and the REASONING COMPILER on the simulated Intel Core i9,
//!    reporting the Table-2 metrics (speedup, sample reduction, sample
//!    efficiency gain).
//! 2. **L1/L2 artifacts** — load the AOT-compiled Llama-3-style transformer
//!    block (Pallas flash-attention + MXU matmul + fused SwiGLU kernels,
//!    lowered by JAX to HLO text) on the PJRT CPU client and validate its
//!    numerics against a residual-path invariant.
//! 3. **Serving** — push batched requests through the dynamic batcher and
//!    report p50/p99 latency and throughput.

use reasoning_compiler::coordinator::{run_e2e, Server, ServerConfig, Strategy, TuneConfig};
use reasoning_compiler::runtime::Manifest;
use reasoning_compiler::tir::workload;

fn main() -> anyhow::Result<()> {
    // ---- 1. end-to-end schedule tuning (Table 2 protocol) -----------------
    let tasks = workload::llama3_e2e(64);
    println!("== 1. tuning the end-to-end Llama-3-8B task set ({} tasks) ==\n", tasks.len());
    let mk = |strategy: Strategy, budget: usize| TuneConfig {
        strategy,
        platform: "core_i9".to_string(),
        budget,
        repeats: 3,
        ..Default::default()
    };
    let es = run_e2e(&tasks, &mk(Strategy::Evolutionary, 1200))?;
    let rc = run_e2e(&tasks, &mk(Strategy::LlmMcts, 300))?;
    println!("{:<22} {:>10} {:>10}", "", "TVM (ES)", "RC");
    println!(
        "{:<22} {:>10} {:>10}",
        "# samples", es.total_samples, rc.total_samples
    );
    println!(
        "{:<22} {:>9.2}x {:>9.2}x",
        "weighted speedup", es.weighted_speedup, rc.weighted_speedup
    );
    let reduction = es.total_samples as f64 / rc.total_samples.max(1) as f64;
    let gain = (rc.weighted_speedup / rc.total_samples.max(1) as f64)
        / (es.weighted_speedup / es.total_samples.max(1) as f64);
    println!("sample reduction: {reduction:.1}x, sample efficiency gain: {gain:.1}x");
    for (name, session) in &rc.tasks {
        println!("  RC {:<18} {:.2}x", name, session.mean_speedup());
    }

    // ---- 2. real numerics through PJRT -------------------------------------
    println!("\n== 2. executing the AOT Llama-3 block on PJRT ==\n");
    let manifest = Manifest::discover()?;
    let mut rt = reasoning_compiler::runtime::Runtime::cpu()?;
    rt.load(&manifest, "llama3_block")?;
    let exe = rt.get("llama3_block").unwrap();
    let mut inputs = exe.random_inputs(11);
    // Scale weights down so the block behaves like a near-identity residual
    // update — an independent numeric sanity check of the compiled graph.
    for w in inputs.iter_mut().skip(2) {
        for v in w.iter_mut() {
            *v *= 1e-3;
        }
    }
    let out = exe.run(&inputs)?;
    let x = &inputs[0];
    let y = &out.outputs[0];
    let drift: f64 = x
        .iter()
        .zip(y)
        .map(|(a, b)| (a - b).abs() as f64)
        .sum::<f64>()
        / x.len() as f64;
    println!(
        "block output: {} elems, finite: {}, mean |y - x| = {:.4} (tiny weights -> residual-dominated)",
        y.len(),
        y.iter().all(|v| v.is_finite()),
        drift
    );
    anyhow::ensure!(y.iter().all(|v| v.is_finite()), "non-finite outputs");
    anyhow::ensure!(drift < 0.5, "residual drift too large: {drift}");

    // ---- 3. batched serving -------------------------------------------------
    println!("\n== 3. serving batched requests ==\n");
    let mut server = Server::start(&manifest, ServerConfig { max_batch: 8 })?;
    server.run_synthetic(128, 3)?;
    println!("{}", server.metrics.report());

    println!("e2e driver complete: search + artifacts + serving all green.");
    Ok(())
}
