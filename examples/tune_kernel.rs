//! Example: tune one kernel and explain the found schedule.
//!
//! ```
//! cargo run --release --example tune_kernel -- [workload] [platform] [budget]
//! ```
//! Runs the REASONING COMPILER on one benchmark, prints the convergence
//! checkpoints, the winning transformation trace, the scheduled TIR and the
//! simulator's latency breakdown for baseline vs tuned — the workflow a
//! performance engineer would use to adopt a schedule.

use reasoning_compiler::coordinator::{run_session, Strategy, TuneConfig};
use reasoning_compiler::cost::{access, simulator, Platform};
use reasoning_compiler::schedule::Schedule;
use reasoning_compiler::tir::{printer, WorkloadId};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let workload = args.first().map(|s| s.as_str()).unwrap_or("deepseek_moe");
    let platform = args.get(1).map(|s| s.as_str()).unwrap_or("core_i9");
    let budget: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(200);

    let w = WorkloadId::from_name(workload).expect("unknown workload");
    let plat = Platform::by_name(platform).expect("unknown platform");
    let cfg = TuneConfig {
        strategy: Strategy::LlmMcts,
        workload: workload.to_string(),
        platform: platform.to_string(),
        budget,
        repeats: 3,
        ..Default::default()
    };
    println!("tuning {} on {} (budget {budget}, 3 repeats)...", w.display(), plat.display);
    let session = run_session(&cfg).expect("tuning session");
    for c in [18, 36, 72, 150, budget] {
        if c <= budget {
            println!("  speedup@{c:<4} = {:.2}x", session.mean_speedup_at(c));
        }
    }

    let run = &session.runs[0];
    let base = w.build();
    let sched = Schedule::new(base.clone());
    let (best, _) = sched.apply_all(&run.best_trace);
    println!("\nwinning trace:\n{}", best.render_trace());
    println!("\nscheduled TIR:\n{}", printer::print_program(&best.current));

    for (label, prog) in [("baseline", &base), ("tuned", &best.current)] {
        println!("--- {label} latency breakdown ({}) ---", plat.display);
        for stage in &prog.stages {
            let a = access::analyze(prog, stage);
            println!("[{}] {}", stage.name, simulator::stage_breakdown(&a, &plat).render());
        }
    }
}
